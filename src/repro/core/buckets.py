"""Modular bucket backends (the paper's pluggable "set algorithms", §3 goal 2).

The paper chains nodes in lock-free linked lists; pointer chasing is hostile
to TPUs, so each backend here is an *array-native* reformulation with the same
observable set semantics:

* ``linear``    — open-addressing, linear probing.  The TPU-native default:
                  bounded vectorized probe sequences, no pointers at all.
* ``twochoice`` — bucketed 2-choice hashing (cuckoo family without eviction):
                  exactly two vector-width bucket reads per lookup.
* ``chain``     — arena-based chained buckets: the faithful analogue of the
                  paper's Michael-list buckets (insert-at-head, logical
                  deletion via state tags, deferred physical reclamation).
                  jnp traversal is lock-step across the query batch: one
                  gather per hop, bounded by ``max_chain``.  The FUSED path
                  never chases pointers: the arena is kept bucket-sorted
                  and tombstone-compacted (``chain_compact_fused``), so
                  probes are per-bucket ``(start, len)`` segment windows —
                  the same slab reductions as the other backends — with a
                  dense-window dirty tail for post-compaction inserts.

Slot/node states mirror the paper's two flag bits:
  LIVE                ~ reachable node
  TOMB                ~ LOGICALLY_REMOVED      (delete; reclaim deferred)
  MIGRATED            ~ IS_BEING_DISTRIBUTED   (rebuild pulled it into hazard)

All operations are *batched*: a batch of Q independent operations is the SPMD
analogue of Q concurrent threads.  Intra-batch conflicts are resolved
deterministically (lowest original index wins), which is one legal
linearization of the paper's concurrent execution.

Every backend exposes:
  make(...) -> Table
  lookup(t, keys)                -> (found[Q], vals[Q], loc[Q])
  insert(t, keys, vals, mask)    -> (t', ok[Q])     # ok=False if present/full
  delete(t, keys, mask)          -> (t', ok[Q])
  extract_chunk(t, cursor, n)    -> (t', hkeys, hvals, hlive, new_cursor)
  count_live(t) -> scalar
  capacity_of(t) -> int (static)
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core import hashing
from repro.core.struct_utils import pytree_dataclass, replace

I32 = jnp.int32
EMPTY, LIVE, TOMB, MIGRATED = I32(0), I32(1), I32(2), I32(3)

BACKENDS = ("linear", "twochoice", "chain")


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def batch_winners(keys: jax.Array, mask: jax.Array) -> jax.Array:
    """First masked occurrence of each distinct key wins (deterministic
    linearization of intra-batch duplicate ops)."""
    q = keys.shape[0]
    idx = jnp.arange(q, dtype=I32)
    order = jnp.lexsort((idx, (~mask).astype(I32), keys))
    ks, ms = keys[order], mask[order]
    first = jnp.concatenate([jnp.ones((1,), bool), ks[1:] != ks[:-1]])
    win_sorted = ms & first
    return jnp.zeros((q,), bool).at[order].set(win_sorted)


def _argpick(hit: jax.Array, vals: jax.Array, axis: int = -1):
    """Select value at the first True along axis (undefined if none)."""
    i = jnp.argmax(hit, axis=axis)
    return jnp.take_along_axis(vals, i[..., None], axis=axis)[..., 0], i


# ---------------------------------------------------------------------------
# linear: open addressing with linear probing
# ---------------------------------------------------------------------------

@pytree_dataclass(meta_fields=("capacity", "max_probes"))
class LinearTable:
    capacity: int
    max_probes: int
    hfn: hashing.HashFn
    key: jax.Array    # [C] i32
    val: jax.Array    # [C] i32
    state: jax.Array  # [C] i32 (EMPTY/LIVE/TOMB/MIGRATED)


def linear_make(capacity: int, hfn: hashing.HashFn, max_probes: int = 64) -> LinearTable:
    # distinct buffers per field (aliased leaves break jit buffer donation)
    def z():
        return jnp.zeros((capacity,), I32)
    return LinearTable(capacity=capacity, max_probes=max_probes, hfn=hfn,
                       key=z(), val=z(), state=z())


def linear_lookup(t: LinearTable, keys: jax.Array):
    found, val, loc, _ = linear_lookup_fwd(t, keys)
    return found, val, loc


def linear_lookup_fwd(t: LinearTable, keys: jax.Array):
    """Lookup that ALSO reports a MIGRATED-slot key match ("tombstone
    forwarding"): a slot whose entry was pulled into the rebuild's hazard
    buffer still holds its key, so the probe that passes over it identifies
    the hazard entry at zero extra cost — the beyond-paper replacement for
    the O(Q x chunk) hazard broadcast compare (EXPERIMENTS.md §Perf).
    Returns (found, val, loc, mig_loc) with mig_loc = -1 if none."""
    c = t.capacity
    h0 = hashing.bucket_of(t.hfn, keys, c)
    q = keys.shape[0]

    def cond(carry):
        active, i = carry[0], carry[5]
        return active.any() & (i < t.max_probes)

    def body(carry):
        active, found, val, loc, mig, i = carry
        pos = (h0 + i) % c
        st = t.state[pos]
        kmatch = t.key[pos] == keys
        hit = active & (st == LIVE) & kmatch
        mig = jnp.where(active & (st == MIGRATED) & kmatch & (mig < 0),
                        pos, mig)
        stop = active & (st == EMPTY)
        val = jnp.where(hit, t.val[pos], val)
        loc = jnp.where(hit, pos, loc)
        found = found | hit
        active = active & ~hit & ~stop
        return active, found, val, loc, mig, i + 1

    init = (jnp.ones((q,), bool), jnp.zeros((q,), bool),
            jnp.zeros((q,), I32), jnp.full((q,), -1, I32),
            jnp.full((q,), -1, I32), jnp.asarray(0, I32))
    _, found, val, loc, mig, _ = jax.lax.while_loop(cond, body, init)
    return found, val, loc, mig


def linear_insert(t: LinearTable, keys: jax.Array, vals: jax.Array, mask: jax.Array):
    c, q = t.capacity, keys.shape[0]
    winner = batch_winners(keys, mask)
    present, _, _ = linear_lookup(t, keys)
    pending0 = winner & ~present
    h0 = hashing.bucket_of(t.hfn, keys, c)
    idx = jnp.arange(q, dtype=I32)

    def body(_, carry):
        key, val, state, pending, off, done = carry
        pos = (h0 + off) % c
        free = pending & (state[pos] != LIVE)
        wpos = jnp.where(free, pos, c)
        claim = jnp.full((c,), q, I32).at[wpos].min(idx, mode="drop")
        won = free & (claim[pos % c] == idx) & (wpos < c)
        wp = jnp.where(won, pos, c)
        key = key.at[wp].set(keys, mode="drop")
        val = val.at[wp].set(vals, mode="drop")
        state = state.at[wp].set(LIVE, mode="drop")
        done = done | won
        pending = pending & ~won
        off = jnp.where(pending, off + 1, off)
        return key, val, state, pending, off, done

    init = (t.key, t.val, t.state, pending0, jnp.zeros((q,), I32), jnp.zeros((q,), bool))
    key, val, state, _, _, done = jax.lax.fori_loop(0, t.max_probes, body, init)
    t = LinearTable(capacity=c, max_probes=t.max_probes, hfn=t.hfn, key=key, val=val, state=state)
    return t, done


def linear_delete(t: LinearTable, keys: jax.Array, mask: jax.Array):
    winner = batch_winners(keys, mask)
    found, _, loc = linear_lookup(t, keys)
    ok = winner & found
    wloc = jnp.where(ok, loc, t.capacity)
    state = t.state.at[wloc].set(TOMB, mode="drop")
    return LinearTable(capacity=t.capacity, max_probes=t.max_probes, hfn=t.hfn,
                       key=t.key, val=t.val, state=state), ok


def linear_extract_chunk(t: LinearTable, cursor: jax.Array, n: int):
    pos = cursor + jnp.arange(n, dtype=I32)
    valid = pos < t.capacity
    cpos = jnp.where(valid, pos, 0)
    live = valid & (t.state[cpos] == LIVE)
    hkeys = jnp.where(live, t.key[cpos], 0)
    hvals = jnp.where(live, t.val[cpos], 0)
    state = t.state.at[jnp.where(live, cpos, t.capacity)].set(MIGRATED, mode="drop")
    new_cursor = jnp.minimum(cursor + n, t.capacity)
    t = LinearTable(capacity=t.capacity, max_probes=t.max_probes, hfn=t.hfn,
                    key=t.key, val=t.val, state=state)
    return t, hkeys, hvals, live, new_cursor


def linear_count_live(t: LinearTable):
    return jnp.sum(t.state == LIVE)


def linear_clear(t: LinearTable) -> LinearTable:
    z = jnp.zeros((t.capacity,), I32)
    return LinearTable(capacity=t.capacity, max_probes=t.max_probes, hfn=t.hfn,
                       key=z, val=z, state=z)


# -- Pallas-accelerated linear paths (kernels/ops.py): same observable set
# semantics as linear_lookup/linear_insert/linear_delete/linear_extract_chunk,
# hot loop in VMEM ----------------------------------------------------------

def linear_lookup_fused(t: LinearTable, keys: jax.Array, *,
                        interpret: bool = True):
    """Kernel-backed lookup.  Returns (found, vals)."""
    from repro.kernels import ops
    h0 = hashing.bucket_of(t.hfn, keys, t.capacity)
    return ops.probe_lookup(t.key, t.val, t.state, h0, keys,
                            max_probes=t.max_probes, interpret=interpret)


def linear_insert_fused(t: LinearTable, keys: jax.Array, vals: jax.Array,
                        mask: jax.Array, *, interpret: bool = True):
    """Kernel-backed insert: batch_winners dedup (the kernel's caller
    contract), then one claim pass + one scatter instead of the
    O(Q x max_probes) jnp claim loop."""
    from repro.kernels import ops
    winner = batch_winners(keys, mask)
    h0 = hashing.bucket_of(t.hfn, keys, t.capacity)
    tk, tv, ts, ok = ops.probe_insert(t.key, t.val, t.state, h0, keys, vals,
                                      winner, max_probes=t.max_probes,
                                      interpret=interpret)
    return LinearTable(capacity=t.capacity, max_probes=t.max_probes,
                       hfn=t.hfn, key=tk, val=tv, state=ts), ok


def linear_delete_fused(t: LinearTable, keys: jax.Array, mask: jax.Array, *,
                        interpret: bool = True):
    """Kernel-backed delete: the location-emitting probe kernel tombstones
    in ONE pass (one sort + one pallas_call + one scatter) instead of the
    jnp lookup-then-scatter double walk."""
    from repro.kernels import ops
    winner = batch_winners(keys, mask)
    h0 = hashing.bucket_of(t.hfn, keys, t.capacity)
    state, ok = ops.probe_delete(t.key, t.val, t.state, h0, keys, winner,
                                 max_probes=t.max_probes, interpret=interpret)
    return LinearTable(capacity=t.capacity, max_probes=t.max_probes,
                       hfn=t.hfn, key=t.key, val=t.val, state=state), ok


def linear_extract_chunk_fused(t: LinearTable, cursor: jax.Array, n: int, *,
                               interpret: bool = True):
    """Kernel-backed rebuild chunk scan: one pallas_call over the resident
    slab window + one MIGRATED scatter; hazard entries come back COMPACTED
    (live entries first) rather than position-aligned — identical as a set,
    which is all the hazard protocol observes."""
    from repro.kernels import ops
    if n > ops.SLAB:   # window contract; fall back to the jnp scan
        return linear_extract_chunk(t, cursor, n)
    state, hk, hv, hl, cur = ops.extract_chunk_fused(
        t.key, t.val, t.state, cursor, chunk=n, interpret=interpret)
    t = LinearTable(capacity=t.capacity, max_probes=t.max_probes, hfn=t.hfn,
                    key=t.key, val=t.val, state=state)
    return t, hk, hv, hl, cur


# ---------------------------------------------------------------------------
# twochoice: bucketed 2-choice hashing (W-wide vector buckets)
# ---------------------------------------------------------------------------

@pytree_dataclass(meta_fields=("nbuckets", "width", "max_rounds"))
class TwoChoiceTable:
    nbuckets: int
    width: int
    max_rounds: int
    hfn_a: hashing.HashFn
    hfn_b: hashing.HashFn
    key: jax.Array    # [B, W] i32
    val: jax.Array    # [B, W] i32
    state: jax.Array  # [B, W] i32


def twochoice_make(nbuckets: int, hfn_a: hashing.HashFn, hfn_b: hashing.HashFn,
                   width: int = 8, max_rounds: int = 8) -> TwoChoiceTable:
    def z():
        return jnp.zeros((nbuckets, width), I32)
    return TwoChoiceTable(nbuckets=nbuckets, width=width, max_rounds=max_rounds,
                          hfn_a=hfn_a, hfn_b=hfn_b, key=z(), val=z(), state=z())


def _tc_rows(t: TwoChoiceTable, keys: jax.Array):
    ba = hashing.bucket_of(t.hfn_a, keys, t.nbuckets)
    bb = hashing.bucket_of(t.hfn_b, keys, t.nbuckets)
    return ba, bb


def twochoice_lookup(t: TwoChoiceTable, keys: jax.Array):
    ba, bb = _tc_rows(t, keys)
    hit_a = (t.key[ba] == keys[:, None]) & (t.state[ba] == LIVE)   # [Q, W]
    hit_b = (t.key[bb] == keys[:, None]) & (t.state[bb] == LIVE)
    fa, fb = hit_a.any(-1), hit_b.any(-1)
    va, sa = _argpick(hit_a, t.val[ba])
    vb, sb = _argpick(hit_b, t.val[bb])
    found = fa | fb
    val = jnp.where(fa, va, vb)
    loc = jnp.where(fa, ba * t.width + sa, jnp.where(fb, bb * t.width + sb, -1))
    return found, val, loc


def twochoice_insert(t: TwoChoiceTable, keys: jax.Array, vals: jax.Array, mask: jax.Array):
    b, w, q = t.nbuckets, t.width, keys.shape[0]
    winner = batch_winners(keys, mask)
    present, _, _ = twochoice_lookup(t, keys)
    pending0 = winner & ~present
    ba, bb = _tc_rows(t, keys)
    idx = jnp.arange(q, dtype=I32)
    nslots = b * w

    def body(r, carry):
        key, val, state, pending, done = carry
        bkt = jnp.where(r % 2 == 0, ba, bb)
        row_free = state[bkt] != LIVE                       # [Q, W]
        has_free = pending & row_free.any(-1)
        slot = jnp.argmax(row_free, axis=-1)
        flat = bkt * w + slot
        wflat = jnp.where(has_free, flat, nslots)
        claim = jnp.full((nslots,), q, I32).at[wflat].min(idx, mode="drop")
        won = has_free & (claim[flat % nslots] == idx) & (wflat < nslots)
        wp = jnp.where(won, flat, nslots)
        key = key.reshape(-1).at[wp].set(keys, mode="drop").reshape(b, w)
        val = val.reshape(-1).at[wp].set(vals, mode="drop").reshape(b, w)
        state = state.reshape(-1).at[wp].set(LIVE, mode="drop").reshape(b, w)
        done = done | won
        pending = pending & ~won
        return key, val, state, pending, done

    init = (t.key, t.val, t.state, pending0, jnp.zeros((q,), bool))
    key, val, state, _, done = jax.lax.fori_loop(0, t.max_rounds, body, init)
    t = TwoChoiceTable(nbuckets=b, width=w, max_rounds=t.max_rounds,
                       hfn_a=t.hfn_a, hfn_b=t.hfn_b, key=key, val=val, state=state)
    return t, done


def twochoice_delete(t: TwoChoiceTable, keys: jax.Array, mask: jax.Array):
    winner = batch_winners(keys, mask)
    found, _, loc = twochoice_lookup(t, keys)
    ok = winner & found
    wloc = jnp.where(ok, loc, t.nbuckets * t.width)
    state = t.state.reshape(-1).at[wloc].set(TOMB, mode="drop").reshape(t.nbuckets, t.width)
    return TwoChoiceTable(nbuckets=t.nbuckets, width=t.width, max_rounds=t.max_rounds,
                          hfn_a=t.hfn_a, hfn_b=t.hfn_b, key=t.key, val=t.val, state=state), ok


def twochoice_extract_chunk(t: TwoChoiceTable, cursor: jax.Array, n: int):
    nslots = t.nbuckets * t.width
    pos = cursor + jnp.arange(n, dtype=I32)
    valid = pos < nslots
    cpos = jnp.where(valid, pos, 0)
    ks, vs, ss = t.key.reshape(-1), t.val.reshape(-1), t.state.reshape(-1)
    live = valid & (ss[cpos] == LIVE)
    hkeys = jnp.where(live, ks[cpos], 0)
    hvals = jnp.where(live, vs[cpos], 0)
    ss = ss.at[jnp.where(live, cpos, nslots)].set(MIGRATED, mode="drop")
    new_cursor = jnp.minimum(cursor + n, nslots)
    t = TwoChoiceTable(nbuckets=t.nbuckets, width=t.width, max_rounds=t.max_rounds,
                       hfn_a=t.hfn_a, hfn_b=t.hfn_b, key=t.key, val=t.val,
                       state=ss.reshape(t.nbuckets, t.width))
    return t, hkeys, hvals, live, new_cursor


def twochoice_count_live(t: TwoChoiceTable):
    return jnp.sum(t.state == LIVE)


def twochoice_clear(t: TwoChoiceTable) -> TwoChoiceTable:
    z = jnp.zeros((t.nbuckets, t.width), I32)
    return TwoChoiceTable(nbuckets=t.nbuckets, width=t.width,
                          max_rounds=t.max_rounds, hfn_a=t.hfn_a,
                          hfn_b=t.hfn_b, key=z, val=z, state=z)


# -- Pallas-accelerated twochoice paths (kernels/ops.py): both row choices
# of a query become two entries of ONE sorted batch — one argsort + one
# pallas_call replace the [Q, W] double-row gathers --------------------------

def twochoice_lookup_fused(t: TwoChoiceTable, keys: jax.Array, *,
                           interpret: bool = True):
    """Kernel-backed 2-choice lookup.  Returns (found, vals, loc) — the same
    triple as ``twochoice_lookup`` so the delete path can reuse ``loc``."""
    from repro.kernels import ops
    ba, bb = _tc_rows(t, keys)
    return ops.twochoice_lookup(t.key, t.val, t.state, ba, bb, keys,
                                interpret=interpret)


def twochoice_insert_fused(t: TwoChoiceTable, keys: jax.Array,
                           vals: jax.Array, mask: jax.Array, *,
                           interpret: bool = True):
    """Kernel-backed 2-choice insert: batch_winners dedup, then one claim
    pass + one scatter (a-row claims shadow b-row claims of the same
    query)."""
    from repro.kernels import ops
    winner = batch_winners(keys, mask)
    ba, bb = _tc_rows(t, keys)
    tk, tv, ts, ok = ops.twochoice_insert(t.key, t.val, t.state, ba, bb,
                                          keys, vals, winner,
                                          max_rounds=t.max_rounds,
                                          interpret=interpret)
    return TwoChoiceTable(nbuckets=t.nbuckets, width=t.width,
                          max_rounds=t.max_rounds, hfn_a=t.hfn_a,
                          hfn_b=t.hfn_b, key=tk, val=tv, state=ts), ok


def twochoice_delete_fused(t: TwoChoiceTable, keys: jax.Array,
                           mask: jax.Array, *, interpret: bool = True):
    """Kernel-backed 2-choice delete: reuses the fused lookup's location
    output — one kernel pass + one tombstone scatter, instead of the jnp
    path's full second ``twochoice_lookup`` row-gather probe."""
    from repro.kernels import ops
    winner = batch_winners(keys, mask)
    ba, bb = _tc_rows(t, keys)
    state, ok = ops.twochoice_delete(t.key, t.val, t.state, ba, bb, keys,
                                     winner, interpret=interpret)
    return TwoChoiceTable(nbuckets=t.nbuckets, width=t.width,
                          max_rounds=t.max_rounds, hfn_a=t.hfn_a,
                          hfn_b=t.hfn_b, key=t.key, val=t.val, state=state), ok


def twochoice_ordered_lookup_fused(t_old: TwoChoiceTable,
                                   t_new: TwoChoiceTable,
                                   hazard_key: jax.Array,
                                   hazard_val: jax.Array,
                                   hazard_live: jax.Array,
                                   keys: jax.Array, *,
                                   interpret: bool = True):
    """Kernel-backed twochoice rebuild-epoch lookup: the whole ordered check
    (old -> hazard -> new, Lemma 4.1) in ONE argsort + ONE probe2-style
    pallas_call — previously two composed fused single-table passes.
    Returns (found, vals)."""
    from repro.kernels import ops
    ba_o, bb_o = _tc_rows(t_old, keys)
    ba_n, bb_n = _tc_rows(t_new, keys)
    return ops.twochoice_ordered_lookup(
        (t_old.key, t_old.val, t_old.state),
        (t_new.key, t_new.val, t_new.state),
        hazard_key, hazard_val, hazard_live,
        ba_o, bb_o, ba_n, bb_n, keys, interpret=interpret)


def twochoice_ordered_delete_fused(t_old: TwoChoiceTable,
                                   t_new: TwoChoiceTable,
                                   hazard_key: jax.Array,
                                   hazard_val: jax.Array,
                                   hazard_live: jax.Array,
                                   keys: jax.Array, mask: jax.Array, *,
                                   interpret: bool = True):
    """Kernel-backed twochoice rebuild-epoch delete (paper Alg. 5): the SAME
    single tc_probe2 pass resolves old-slot / hazard-index / new-slot;
    three scatters land the result.  Returns the raw
    (old_state', new_state', hazard_live', ok[Q]) — the dhash layer
    reassembles its pytrees."""
    from repro.kernels import ops
    winner = batch_winners(keys, mask)
    ba_o, bb_o = _tc_rows(t_old, keys)
    ba_n, bb_n = _tc_rows(t_new, keys)
    return ops.twochoice_ordered_delete(
        (t_old.key, t_old.val, t_old.state),
        (t_new.key, t_new.val, t_new.state),
        hazard_key, hazard_val, hazard_live,
        ba_o, bb_o, ba_n, bb_n, keys, winner, interpret=interpret)


def twochoice_extract_chunk_fused(t: TwoChoiceTable, cursor: jax.Array,
                                  n: int, *, interpret: bool = True):
    """Kernel-backed 2-choice rebuild chunk scan: the extract kernel runs on
    the row-major flattened arrays (the scan order is identical)."""
    from repro.kernels import ops
    if n > ops.SLAB:
        return twochoice_extract_chunk(t, cursor, n)
    state, hk, hv, hl, cur = ops.extract_chunk_fused(
        t.key.reshape(-1), t.val.reshape(-1), t.state.reshape(-1), cursor,
        chunk=n, interpret=interpret)
    t = TwoChoiceTable(nbuckets=t.nbuckets, width=t.width,
                       max_rounds=t.max_rounds, hfn_a=t.hfn_a, hfn_b=t.hfn_b,
                       key=t.key, val=t.val,
                       state=state.reshape(t.nbuckets, t.width))
    return t, hk, hv, hl, cur


# ---------------------------------------------------------------------------
# chain: arena-based chained buckets (paper-faithful Michael-list analogue)
# ---------------------------------------------------------------------------

@pytree_dataclass(meta_fields=("nbuckets", "arena", "max_chain"))
class ChainTable:
    nbuckets: int
    arena: int        # node capacity N
    max_chain: int    # traversal bound (>= max expected chain incl. tombstones)
    hfn: hashing.HashFn
    akey: jax.Array   # [N] i32
    aval: jax.Array   # [N] i32
    anext: jax.Array  # [N] i32 (-1 terminates)
    astate: jax.Array # [N] i32
    heads: jax.Array  # [B] i32 (-1 empty)
    free_stack: jax.Array  # [N] i32 - free node indices live at [0, free_top)
    free_top: jax.Array    # scalar i32
    # arena-sorted layout metadata (the fused path's view of the same arena):
    # [0, sorted_upto) holds the bucket-sorted, tombstone-compacted segments
    # (bucket b's nodes at [bstart[b], bstart[b]+blen[b])), and nodes
    # allocated SINCE the last compaction occupy the contiguous "dirty" tail
    # [sorted_upto, arena - free_top).  ``chain_dirty(t)`` derives the dirty
    # count; ``chain_compact_fused`` restores dirty == 0.
    bstart: jax.Array      # [B] i32 - sorted-segment start per bucket
    blen: jax.Array        # [B] i32 - sorted-segment length per bucket
    sorted_upto: jax.Array # scalar i32 - arena prefix in bucket-sorted order


def chain_make(nbuckets: int, arena: int, hfn: hashing.HashFn, max_chain: int = 64) -> ChainTable:
    n = arena
    # free_stack is DESCENDING so pops allocate ascending positions: the
    # allocated region is always the contiguous prefix [0, n - free_top),
    # which is what keeps the fused path's dirty tail a dense window.
    return ChainTable(
        nbuckets=nbuckets, arena=n, max_chain=max_chain, hfn=hfn,
        akey=jnp.zeros((n,), I32), aval=jnp.zeros((n,), I32),
        anext=jnp.full((n,), -1, I32), astate=jnp.zeros((n,), I32),
        heads=jnp.full((nbuckets,), -1, I32),
        free_stack=n - 1 - jnp.arange(n, dtype=I32),
        free_top=jnp.asarray(n, I32),
        bstart=jnp.zeros((nbuckets,), I32), blen=jnp.zeros((nbuckets,), I32),
        sorted_upto=jnp.asarray(0, I32))


def chain_dirty(t: ChainTable) -> jax.Array:
    """Scalar i32: nodes allocated since the last compaction (they live at
    [sorted_upto, arena - free_top) — allocation is always a prefix)."""
    return t.arena - t.free_top - t.sorted_upto


def chain_lookup(t: ChainTable, keys: jax.Array, bucket: jax.Array | None = None):
    """Lock-step batched traversal with DYNAMIC termination: the step cost is
    the longest still-active chain in the batch, not the static bound — so
    collision attacks show up in wall time exactly as they do on the paper's
    pointer-chasing implementations."""
    q = keys.shape[0]
    b = hashing.bucket_of(t.hfn, keys, t.nbuckets) if bucket is None else bucket
    cur0 = t.heads[b]

    def cond(carry):
        cur, found, _, _, fuel = carry
        return ((cur >= 0) & ~found).any() & (fuel > 0)

    def body(carry):
        cur, found, val, loc, fuel = carry
        valid = cur >= 0
        c = jnp.where(valid, cur, 0)
        hit = valid & (t.astate[c] == LIVE) & (t.akey[c] == keys) & ~found
        val = jnp.where(hit, t.aval[c], val)
        loc = jnp.where(hit, cur, loc)
        found = found | hit
        step = valid & ~found
        cur = jnp.where(step, t.anext[c], jnp.where(found, cur, -1))
        return cur, found, val, loc, fuel - 1

    init = (cur0, jnp.zeros((q,), bool), jnp.zeros((q,), I32),
            jnp.full((q,), -1, I32), jnp.asarray(t.max_chain, I32))
    _, found, val, loc, _ = jax.lax.while_loop(cond, body, init)
    return found, val, loc


def _chain_link(t: ChainTable, keys, node, can, bucket: jax.Array | None = None):
    """Insert nodes ``node`` (where can) at the heads of their buckets,
    preserving original-index order within each bucket group."""
    q = keys.shape[0]
    b = hashing.bucket_of(t.hfn, keys, t.nbuckets) if bucket is None else bucket
    sortkey = jnp.where(can, b, t.nbuckets)
    idx = jnp.arange(q, dtype=I32)
    order = jnp.lexsort((idx, sortkey))
    sb, snode, scan = sortkey[order], node[order], can[order]
    nxt_same = jnp.concatenate([snode[1:], jnp.full((1,), -1, I32)])
    same_bucket = jnp.concatenate([sb[1:] == sb[:-1], jnp.zeros((1,), bool)])
    old_head = t.heads[jnp.where(scan, sb, 0)]
    nxt = jnp.where(same_bucket, nxt_same, jnp.where(scan, old_head, -1))
    anext = t.anext.at[jnp.where(scan, snode, t.arena)].set(nxt, mode="drop")
    is_start = jnp.concatenate([jnp.ones((1,), bool), sb[1:] != sb[:-1]])
    heads = t.heads.at[jnp.where(scan & is_start, sb, t.nbuckets)].set(snode, mode="drop")
    return anext, heads


def chain_insert(t: ChainTable, keys: jax.Array, vals: jax.Array, mask: jax.Array,
                 bucket: jax.Array | None = None):
    q, n = keys.shape[0], t.arena
    winner = batch_winners(keys, mask)
    present, _, _ = chain_lookup(t, keys, bucket)
    want = winner & ~present
    rank = jnp.cumsum(want.astype(I32)) - 1
    can = want & (rank < t.free_top)
    node = t.free_stack[jnp.where(can, t.free_top - 1 - rank, 0)]
    wnode = jnp.where(can, node, n)
    akey = t.akey.at[wnode].set(keys, mode="drop")
    aval = t.aval.at[wnode].set(vals, mode="drop")
    astate = t.astate.at[wnode].set(LIVE, mode="drop")
    t1 = replace(t, akey=akey, aval=aval, astate=astate)
    anext, heads = _chain_link(t1, keys, node, can, bucket)
    free_used = jnp.sum(can.astype(I32))
    # new nodes extend the dirty tail; the sorted segments are untouched
    t2 = replace(t1, anext=anext, heads=heads,
                 free_top=t.free_top - free_used)
    return t2, can


def chain_delete(t: ChainTable, keys: jax.Array, mask: jax.Array,
                 bucket: jax.Array | None = None):
    winner = batch_winners(keys, mask)
    found, _, loc = chain_lookup(t, keys, bucket)
    ok = winner & found
    wloc = jnp.where(ok, loc, t.arena)
    astate = t.astate.at[wloc].set(TOMB, mode="drop")
    return replace(t, astate=astate), ok


def chain_extract_chunk(t: ChainTable, cursor: jax.Array, n: int):
    pos = cursor + jnp.arange(n, dtype=I32)
    valid = pos < t.arena
    cpos = jnp.where(valid, pos, 0)
    live = valid & (t.astate[cpos] == LIVE)
    hkeys = jnp.where(live, t.akey[cpos], 0)
    hvals = jnp.where(live, t.aval[cpos], 0)
    astate = t.astate.at[jnp.where(live, cpos, t.arena)].set(MIGRATED, mode="drop")
    new_cursor = jnp.minimum(cursor + n, t.arena)
    return replace(t, astate=astate), hkeys, hvals, live, new_cursor


def chain_compact(t: ChainTable) -> ChainTable:
    """Physically reclaim tombstones: rebuild all chains from live nodes.

    The paper defers physical unlinking to later traversals / call_rcu; the
    batched analogue is a periodic vectorized compaction (also doubles as the
    post-rebuild reclamation of the old arena)."""
    live = t.astate == LIVE
    fresh = chain_make(t.nbuckets, t.arena, t.hfn, t.max_chain)
    t2, _ = chain_insert(fresh, jnp.where(live, t.akey, 0), t.aval, live)
    return t2


def chain_count_live(t: ChainTable):
    return jnp.sum(t.astate == LIVE)


def chain_clear(t: ChainTable) -> ChainTable:
    n = t.arena
    return replace(
        t, akey=jnp.zeros((n,), I32), aval=jnp.zeros((n,), I32),
        anext=jnp.full((n,), -1, I32), astate=jnp.zeros((n,), I32),
        heads=jnp.full((t.nbuckets,), -1, I32),
        free_stack=n - 1 - jnp.arange(n, dtype=I32),
        free_top=jnp.asarray(n, I32),
        bstart=jnp.zeros((t.nbuckets,), I32),
        blen=jnp.zeros((t.nbuckets,), I32),
        sorted_upto=jnp.asarray(0, I32))


# -- Pallas-accelerated chain paths (kernels/ops.py): the arena is kept in
# bucket-sorted, tombstone-compacted order (per-bucket (start, len) segments
# replace head/next pointer chasing on the read path), so chain probes are
# the same slab-window reductions the other backends use.  Nodes inserted
# since the last compaction live in the contiguous dirty tail and are
# resolved by a dense window compare (the hazard-buffer treatment); when the
# tail outgrows ops.DIRTY_CAP the ops escape to the pointer-chasing jnp
# reference via the gated fallback ---------------------------------------

def _chain_parts(t: ChainTable):
    """The raw-array views the chain ops consume: arena triple, link pair
    (for the pointer-chasing fallback), segment quad."""
    return ((t.akey, t.aval, t.astate), (t.anext, t.heads),
            (t.bstart, t.blen, t.sorted_upto, chain_dirty(t)))


def chain_lookup_fused(t: ChainTable, keys: jax.Array, *,
                       interpret: bool = True):
    """Kernel-backed chain lookup over the arena-sorted layout.  Returns
    (found, vals, loc) — ``loc`` is the arena node index (-1 if absent), so
    the fused delete never probes twice."""
    from repro.kernels import ops
    b = hashing.bucket_of(t.hfn, keys, t.nbuckets)
    return ops.chain_lookup_fused(*_chain_parts(t), b, keys,
                                  max_chain=t.max_chain, interpret=interpret)


def chain_insert_fused(t: ChainTable, keys: jax.Array, vals: jax.Array,
                       mask: jax.Array, *, interpret: bool = True):
    """Kernel-backed chain insert: batch_winners dedup, ONE sort keyed on
    the bucket (it orders both the presence-probe tiles AND the head
    linking), one presence pallas_call, then vectorized tail allocation +
    segmented head relink — no pointer chasing.  New nodes extend the dirty
    tail; call ``chain_maybe_compact`` to restore the sorted invariant."""
    from repro.kernels import ops
    winner = batch_winners(keys, mask)
    b = hashing.bucket_of(t.hfn, keys, t.nbuckets)
    arena_t, links, seg = _chain_parts(t)
    akey, aval, astate, anext, heads, free_top, ok = ops.chain_insert_fused(
        arena_t, links, seg, t.free_stack, t.free_top, b, keys, vals, winner,
        max_chain=t.max_chain, interpret=interpret)
    return replace(t, akey=akey, aval=aval, astate=astate, anext=anext,
                   heads=heads, free_top=free_top), ok


def chain_delete_fused(t: ChainTable, keys: jax.Array, mask: jax.Array, *,
                       interpret: bool = True):
    """Kernel-backed chain delete: the location-emitting probe (sorted
    segment window + dirty-tail compare) tombstones in ONE pass."""
    from repro.kernels import ops
    winner = batch_winners(keys, mask)
    b = hashing.bucket_of(t.hfn, keys, t.nbuckets)
    astate, ok = ops.chain_delete_fused(*_chain_parts(t), b, keys, winner,
                                        max_chain=t.max_chain,
                                        interpret=interpret)
    return replace(t, astate=astate), ok


def chain_ordered_lookup_fused(t_old: ChainTable, t_new: ChainTable,
                               hazard_key: jax.Array, hazard_val: jax.Array,
                               hazard_live: jax.Array, keys: jax.Array, *,
                               interpret: bool = True):
    """Kernel-backed chain rebuild-epoch lookup: the whole ordered check
    (old -> hazard -> new, Lemma 4.1) in ONE sort + ONE chain_probe2
    pallas_call, with the PR 3 two-level tile map covering grown new
    arenas.  Returns (found, vals)."""
    from repro.kernels import ops
    b_old = hashing.bucket_of(t_old.hfn, keys, t_old.nbuckets)
    b_new = hashing.bucket_of(t_new.hfn, keys, t_new.nbuckets)
    return ops.chain_ordered_lookup(
        *_chain_parts(t_old), *_chain_parts(t_new),
        hazard_key, hazard_val, hazard_live, b_old, b_new, keys,
        max_chain=max(t_old.max_chain, t_new.max_chain), interpret=interpret)


def chain_ordered_delete_fused(t_old: ChainTable, t_new: ChainTable,
                               hazard_key: jax.Array, hazard_val: jax.Array,
                               hazard_live: jax.Array, keys: jax.Array,
                               mask: jax.Array, *, interpret: bool = True):
    """Kernel-backed chain rebuild-epoch delete (paper Alg. 5): the SAME
    single chain_probe2 pass resolves old-node / hazard-index / new-node;
    three scatters land the result.  Returns the raw
    (old_astate', new_astate', hazard_live', ok[Q])."""
    from repro.kernels import ops
    winner = batch_winners(keys, mask)
    b_old = hashing.bucket_of(t_old.hfn, keys, t_old.nbuckets)
    b_new = hashing.bucket_of(t_new.hfn, keys, t_new.nbuckets)
    return ops.chain_ordered_delete(
        *_chain_parts(t_old), *_chain_parts(t_new),
        hazard_key, hazard_val, hazard_live, b_old, b_new, keys, winner,
        max_chain=max(t_old.max_chain, t_new.max_chain), interpret=interpret)


def chain_extract_chunk_fused(t: ChainTable, cursor: jax.Array, n: int, *,
                              interpret: bool = True):
    """Kernel-backed rebuild chunk scan: the arena is a flat array, so the
    extract kernel runs verbatim (positions are scan order)."""
    from repro.kernels import ops
    if n > ops.SLAB:   # window contract; fall back to the jnp scan
        return chain_extract_chunk(t, cursor, n)
    astate, hk, hv, hl, cur = ops.extract_chunk_fused(
        t.akey, t.aval, t.astate, cursor, chunk=n, interpret=interpret)
    return replace(t, astate=astate), hk, hv, hl, cur


def chain_compact_fused(t: ChainTable) -> ChainTable:
    """Restore the arena-sorted invariant: ONE segmented sort keyed on
    (bucket, arena index) with dead nodes pushed to the end, the compaction
    gather, per-bucket (start, len) offsets, and a vectorized pointer
    rebuild (node i chains to i+1 within its bucket).  Physically reclaims
    tombstones/migrated nodes; dirty count drops to 0."""
    from repro.kernels import ops
    b = hashing.bucket_of(t.hfn, t.akey, t.nbuckets)
    (akey, aval, astate, anext, heads, free_stack, free_top, bstart, blen,
     sorted_upto) = ops.chain_compact_fused(t.akey, t.aval, t.astate, b,
                                            nbuckets=t.nbuckets)
    return replace(t, akey=akey, aval=aval, astate=astate, anext=anext,
                   heads=heads, free_stack=free_stack, free_top=free_top,
                   bstart=bstart, blen=blen, sorted_upto=sorted_upto)


def chain_maybe_compact(t: ChainTable, *,
                        threshold: int | None = None) -> ChainTable:
    """Compaction trigger: re-sort the arena iff the dirty tail has outgrown
    the dense-window coverage (``ops.DIRTY_CAP`` by default) — the gate that
    keeps the fused chain ops on the kernel path.  cond-gated, so the clean
    steady state never pays the sort."""
    from repro.kernels import ops
    thresh = ops.DIRTY_CAP if threshold is None else threshold
    return jax.lax.cond(chain_dirty(t) > thresh, chain_compact_fused,
                        lambda tt: tt, t)


# ---------------------------------------------------------------------------
# dispatch facade
# ---------------------------------------------------------------------------

_OPS: dict[str, dict[str, Any]] = {
    "linear": dict(lookup=linear_lookup, insert=linear_insert, delete=linear_delete,
                   extract_chunk=linear_extract_chunk, count_live=linear_count_live,
                   clear=linear_clear),
    "twochoice": dict(lookup=twochoice_lookup, insert=twochoice_insert, delete=twochoice_delete,
                      extract_chunk=twochoice_extract_chunk, count_live=twochoice_count_live,
                      clear=twochoice_clear),
    "chain": dict(lookup=chain_lookup, insert=chain_insert, delete=chain_delete,
                  extract_chunk=chain_extract_chunk, count_live=chain_count_live,
                  clear=chain_clear),
}


def backend_of(table) -> str:
    if isinstance(table, LinearTable):
        return "linear"
    if isinstance(table, TwoChoiceTable):
        return "twochoice"
    if isinstance(table, ChainTable):
        return "chain"
    raise TypeError(type(table))


def lookup(t, keys):
    return _OPS[backend_of(t)]["lookup"](t, keys)


def insert(t, keys, vals, mask):
    return _OPS[backend_of(t)]["insert"](t, keys, vals, mask)


def delete(t, keys, mask):
    return _OPS[backend_of(t)]["delete"](t, keys, mask)


def extract_chunk(t, cursor, n):
    return _OPS[backend_of(t)]["extract_chunk"](t, cursor, n)


def count_live(t):
    return _OPS[backend_of(t)]["count_live"](t)


def clear(t):
    """Empty the table in place (shape/hash-function preserving, jittable) —
    the on-device reset of a drained table before it becomes the next rebuild
    target."""
    return _OPS[backend_of(t)]["clear"](t)


def capacity_of(t) -> int:
    if isinstance(t, LinearTable):
        return t.capacity
    if isinstance(t, TwoChoiceTable):
        return t.nbuckets * t.width
    if isinstance(t, ChainTable):
        return t.arena
    raise TypeError(type(t))
