"""Train-step builder: grad accumulation, sharding, donation, DHash-router
state threading.

The returned step is pure (state, batch) -> (state, metrics) so it jits with
in/out shardings and donated state.  For hash-router MoE archs the DHash
override table rides in the state and advances one rebuild transition per
step — a live router rebalance never blocks training (the paper's property,
exercised in the training loop itself).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import dhash
from repro.models import model
from repro.optim import optimizer as opt_lib

F32 = jnp.float32


def make_router_table(cfg: ArchConfig, *, capacity: int = 4096) -> dhash.DHashState | None:
    if not (cfg.n_experts and cfg.use_hash_router):
        return None
    return dhash.make("linear", capacity=capacity, chunk=256, seed=17)


def init_state(cfg: ArchConfig, opt_cfg: opt_lib.OptConfig, key: jax.Array) -> dict:
    from repro.models import transformer
    params = transformer.init_params(cfg, key)
    state = {"params": params, "opt": opt_lib.init_opt_state(params, opt_cfg)}
    rt = make_router_table(cfg)
    if rt is not None:
        state["router_table"] = rt
    return state


def train_step(state: dict, batch: dict, *, cfg: ArchConfig,
               opt_cfg: opt_lib.OptConfig, grad_accum: int = 1):
    """One optimizer step. With grad_accum > 1, batch leaves carry a leading
    [A, ...] microbatch axis consumed by a scan (activation memory / A)."""
    rt = state.get("router_table")

    def loss(p, b):
        return model.loss_fn(p, cfg, b, router_table=rt)

    vg = jax.value_and_grad(loss, has_aux=True)
    if grad_accum == 1:
        (l, metrics), grads = vg(state["params"], batch)
    else:
        def acc(carry, mb):
            gsum, lsum = carry
            (li, mi), gi = vg(state["params"], mb)
            return (jax.tree_util.tree_map(jnp.add, gsum, gi), lsum + li), mi
        g0 = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, F32),
                                    state["params"])
        (grads, lsum), mlast = jax.lax.scan(acc, (g0, jnp.zeros((), F32)), batch)
        grads = jax.tree_util.tree_map(lambda g: g / grad_accum, grads)
        l, metrics = lsum / grad_accum, mlast

    params, opt, om = opt_lib.apply_updates(state["params"], grads,
                                            state["opt"], opt_cfg)
    new_state = {"params": params, "opt": opt}
    if rt is not None:
        # background rebuild progress: one transition per step, never blocking
        new_state["router_table"] = dhash.rebuild_step(rt)
    metrics = dict(metrics, loss=l, **om)
    return new_state, metrics


def rebalance_router(state: dict, expert_load: jax.Array, cfg: ArchConfig,
                     *, hot_frac: float = 2.0) -> dict:
    """Host-level reaction to expert-load skew (the paper's attack response):
    insert overrides steering traffic away from hot experts, or trigger a
    full rebuild of the override table with a fresh hash seed."""
    rt = state.get("router_table")
    if rt is None:
        return state
    import numpy as np
    load = np.asarray(jax.device_get(expert_load), dtype=np.float64)
    mean = max(load.mean(), 1.0)
    if load.max() > hot_frac * mean and not bool(jax.device_get(rt.rebuilding)):
        state = dict(state, router_table=dhash.rebuild_start(
            rt, seed=int(load.sum()) % (2**31 - 1) + 1))
    return state
