"""Deterministic, stateless, elastic synthetic LM data pipeline.

Every batch is a pure function of (seed, step, shard_index) via counter-mode
hashing — the strongest possible fault-tolerance/elasticity posture: resuming
from a checkpointed step reproduces the exact token stream on any number of
hosts, with no iterator state to persist.  Structure: documents with
power-law-ish lengths separated by EOS, zipf-distributed token ids (so the
hash-router and dedup workloads see realistic frequency skew — the paper's
"burst" regime is reproduced by skewing the zipf exponent).

The DHash tie-in: ``dedup_batch`` drops repeated documents using a DHash
fingerprint table — a data-pipeline client of the paper's structure.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dhash, hashing

I32 = jnp.int32


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mean_doc_len: int = 512
    zipf_a: float = 1.2          # token frequency skew
    eos_id: int = 0


def _u01(fn: hashing.HashFn, x: jax.Array) -> jax.Array:
    return hashing.hash_u32(fn, x).astype(jnp.float32) / np.float32(2 ** 32)


def synth_batch(cfg: DataConfig, step: int | jax.Array, *, shard: int = 0,
                nshards: int = 1, mrope: bool = False) -> dict:
    """Batch for (step, shard). Local batch = global_batch // nshards."""
    b = cfg.global_batch // nshards
    s = cfg.seq_len
    fn = hashing.HashFn(kind="mix32",
                        seeds=jnp.asarray([cfg.seed * 2654435761 % 2**32 or 1,
                                           0x9E3779B9], jnp.uint32))
    base = (jnp.asarray(step, I32) * cfg.global_batch + shard * b) * s
    idx = base + jnp.arange(b, dtype=I32)[:, None] * s + jnp.arange(s, dtype=I32)[None, :]
    # zipf-ish token ids: u^( -1/(a-1) ) rank transform, clipped to vocab
    u = jnp.clip(_u01(fn, idx), 1e-6, 1.0)
    rank = jnp.power(u, -1.0 / (cfg.zipf_a - 1.0))
    tokens = jnp.clip(rank.astype(I32), 0, cfg.vocab_size - 1)
    # document structure: EOS roughly every mean_doc_len tokens
    is_eos = _u01(fn, idx + 0x5BD1E995) < (1.0 / cfg.mean_doc_len)
    tokens = jnp.where(is_eos, cfg.eos_id, tokens)
    labels = jnp.concatenate([tokens[:, 1:], jnp.full((b, 1), cfg.eos_id, I32)], 1)
    batch = {"tokens": tokens, "labels": labels,
             "loss_mask": jnp.ones((b, s), bool)}
    if mrope:
        pos = jnp.broadcast_to(jnp.arange(s, dtype=I32), (b, s))
        batch["positions"] = jnp.stack([pos, pos, pos])     # t/h/w streams
    return batch


def synth_embeds(cfg: DataConfig, step: int, d_model: int, *, shard: int = 0,
                 nshards: int = 1, dtype=jnp.bfloat16) -> jax.Array:
    """Stub modality frontend: precomputed frame/patch embeddings (spec'd
    deterministic), for the [audio]/[vlm] architectures."""
    b = cfg.global_batch // nshards
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step * 1000 + shard)
    return (jax.random.normal(key, (b, cfg.seq_len, d_model), jnp.float32)
            .astype(dtype))


# ---------------------------------------------------------------------------
# DHash client: streaming dedup
# ---------------------------------------------------------------------------

def doc_fingerprints(tokens: jax.Array, *, block: int = 128) -> jax.Array:
    """Rolling content hash per block of tokens: [B, S//block] i32 (avoids
    u32 sentinel collisions by clearing the sign bit)."""
    b, s = tokens.shape
    n = s // block
    blocks = tokens[:, : n * block].reshape(b * n, block)
    h = jnp.full((b * n,), jnp.uint32(0x811C9DC5))
    for i in range(block):
        h = hashing.hash_combine(h, blocks[:, i])
    return (h & jnp.uint32(0x7FFFFFFF)).astype(I32).reshape(b, n)


def dedup_batch(table: dhash.DHashState, tokens: jax.Array, *, block: int = 128):
    """Mask out token blocks whose fingerprint was already seen; insert the
    fresh ones. Returns (table', keep_mask [B, S])."""
    fps = doc_fingerprints(tokens, block=block)            # [B, n]
    flat = fps.reshape(-1)
    seen, _ = dhash.lookup(table, flat)
    table, _ = dhash.insert(table, flat, jnp.zeros_like(flat), ~seen)
    keep = ~seen.reshape(fps.shape)                        # [B, n]
    b, s = tokens.shape
    n = s // block
    keep_tok = jnp.repeat(keep, block, axis=1)
    if n * block < s:
        keep_tok = jnp.concatenate(
            [keep_tok, jnp.ones((b, s - n * block), bool)], axis=1)
    return table, keep_tok
