"""Jit'd wrappers around the Pallas kernels: padding, sorting, fallback.

``probe_lookup`` is a drop-in accelerated equivalent of
``ref.probe_lookup_ref`` (and of ``buckets.linear_lookup``'s inner loop):
exact results for every query — tiles whose probe window escapes the
VMEM-resident slab are recomputed by the jnp fallback (rare: requires > 8192
contiguously occupied slots of hash skew).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.probe import QT, SLAB, probe_lookup_tiles

I32 = jnp.int32


def _pad_to(x: jax.Array, n: int, fill=0):
    return jnp.pad(x, (0, n - x.shape[0]), constant_values=fill)


@partial(jax.jit, static_argnames=("max_probes", "interpret"))
def probe_lookup(tkey: jax.Array, tval: jax.Array, tstate: jax.Array,
                 h0: jax.Array, qkey: jax.Array, *, max_probes: int = 64,
                 interpret: bool = True):
    """Batched linear-probe lookup. Returns (found[Q], val[Q]).

    Args:
      tkey/tval/tstate: table arrays [C].
      h0: start slot per query (hash(key) % C), [Q].
      qkey: query keys [Q].
    """
    c = tkey.shape[0]
    q = qkey.shape[0]

    # 1. pad the table with a wrapped copy so probes never wrap, then to a
    #    SLAB multiple (padding slots are EMPTY => probes terminate there).
    cpad = -(-(c + max_probes) // SLAB) * SLAB + SLAB  # +SLAB: block s+1 always valid
    tk = _pad_to(jnp.concatenate([tkey, tkey[:max_probes]]), cpad)
    tv = _pad_to(jnp.concatenate([tval, tval[:max_probes]]), cpad)
    ts = _pad_to(jnp.concatenate([tstate, tstate[:max_probes]]), cpad)

    # 2. sort queries by start slot so tiles hit contiguous slabs
    order = jnp.argsort(h0)
    h0s, qks = h0[order], qkey[order]
    qpad = -(-q // QT) * QT
    # pad queries with h0=0 sentinels (complete, harmless)
    h0s = _pad_to(h0s, qpad)
    qks = _pad_to(qks, qpad)

    # 3. per-tile slab block: floor(min h0 of tile / SLAB)
    tiles = qpad // QT
    slab_base = (h0s.reshape(tiles, QT)[:, 0] // SLAB).astype(I32)
    slab_base = jnp.minimum(slab_base, cpad // SLAB - 2)

    found_s, val_s, complete_s = probe_lookup_tiles(
        tk, tv, ts, h0s, qks, slab_base, max_probes=max_probes,
        interpret=interpret)

    # 4. fallback: recompute incomplete queries with the jnp oracle
    #    (masked: cost is one extra pass only in the skew regime)
    need = ~complete_s
    fb_found, fb_val = ref.probe_lookup_ref(
        tkey, tval, tstate, jnp.where(need, h0s % c, 0),
        qks, max_probes)
    found_s = jnp.where(need, fb_found, found_s)
    val_s = jnp.where(need, fb_val, val_s)

    # 5. unsort (order permutes [0, q); tail positions are padding)
    found = jnp.zeros((q,), jnp.bool_).at[order].set(found_s[:q])
    val = jnp.zeros((q,), I32).at[order].set(val_s[:q])
    return found, val


@partial(jax.jit, static_argnames=("max_probes", "interpret"))
def ordered_lookup(old_tables, new_tables, hazard_key, hazard_val, hazard_live,
                   h0_old, h0_new, qkey, *, max_probes: int = 64,
                   interpret: bool = True):
    """Fused rebuild-epoch lookup: old table -> hazard buffer -> new table
    (the paper's Lemma 4.1 order), each table pass via the Pallas kernel."""
    f_old, v_old = probe_lookup(*old_tables, h0_old, qkey,
                                max_probes=max_probes, interpret=interpret)
    eq = (qkey[:, None] == hazard_key[None, :]) & hazard_live[None, :]
    f_hz = eq.any(-1)
    v_hz = jnp.take(hazard_val, jnp.argmax(eq, axis=-1))
    f_new, v_new = probe_lookup(*new_tables, h0_new, qkey,
                                max_probes=max_probes, interpret=interpret)
    found = f_old | f_hz | f_new
    val = jnp.where(f_old, v_old, jnp.where(f_hz, v_hz, v_new))
    return found, val
