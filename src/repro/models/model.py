"""Loss and logits heads on top of the transformer assembly."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer
from repro.models.layers import chunked_cross_entropy

F32 = jnp.float32


def loss_fn(params: dict, cfg: ArchConfig, batch: dict, router_table=None):
    """Next-token (or masked-prediction for encoder-only) CE loss.

    batch: tokens/embeds [+positions], labels [B,S] (already shifted),
    optional loss_mask [B,S].
    Returns (loss, metrics).
    """
    hidden, aux = transformer.forward_train(params, cfg, batch, router_table)
    w = transformer.unembed_matrix(params, cfg)
    loss = chunked_cross_entropy(
        hidden, w, batch["labels"],
        chunk=min(cfg.loss_chunk, hidden.shape[1]),
        logit_softcap=cfg.logit_softcap,
        mask=batch.get("loss_mask"))
    total = loss + 0.01 * aux["moe_aux"]
    metrics = {"ce": loss, "moe_aux": aux["moe_aux"],
               "expert_load": aux["expert_load"]}
    return total, metrics


def decode_logits(params: dict, cfg: ArchConfig, tokens1, cache: dict,
                  router_table=None):
    """One decode step -> (logits [B,V], cache')."""
    hidden, cache = transformer.forward_decode(params, cfg, tokens1, cache,
                                               router_table)
    w = transformer.unembed_matrix(params, cfg)
    logits = jnp.einsum("bsd,dv->bsv", hidden, w).astype(F32)
    if cfg.logit_softcap > 0:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits[:, 0], cache
