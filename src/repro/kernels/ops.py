"""Jit'd wrappers around the Pallas kernels: padding, sorting, fallback.

``probe_lookup`` is a drop-in accelerated equivalent of
``ref.probe_lookup_ref`` (and of ``buckets.linear_lookup``'s inner loop);
``ordered_lookup_fused`` is the accelerated rebuild-epoch path (one sort +
one pallas_call for the whole old->hazard->new ordered check);
``probe_insert`` is the accelerated write path (claim kernel + one scatter).

Exactness contract shared by all three: queries whose probe window escapes
the VMEM-resident slab (hash skew), or whose insert claim collides across
tiles, are recomputed by the jnp oracle fallback — which is gated behind
``jax.lax.cond`` so the steady state (no escapes) never pays for it.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.probe import (QT, SLAB, probe2_tiles, probe_insert_tiles,
                                 probe_lookup_tiles)

I32 = jnp.int32
LIVE = 1


def _pad_to(x: jax.Array, n: int, fill=0):
    return jnp.pad(x, (0, n - x.shape[0]), constant_values=fill)


def _pad_table(arrays, c: int, max_probes: int):
    """Pad table arrays with a wrapped copy (probes never wrap in-kernel),
    then to a SLAB multiple plus one spare block (block s+1 always valid);
    padding slots are EMPTY so probes terminate there."""
    cpad = -(-(c + max_probes) // SLAB) * SLAB + SLAB
    return tuple(_pad_to(jnp.concatenate([a, a[:max_probes]]), cpad)
                 for a in arrays)


def _sort_pad_queries(order, qpad, *arrays):
    """Apply the shared sort and pad to a QT multiple by REPLICATING the last
    sorted element (edge padding).  Padding with a constant sentinel would
    break the slab math: an h0=0 pad in a tile whose slab base is > 0 reads
    complete=False and drags min-based tile bases to block 0, firing the
    oracle fallback on every non-QT-multiple batch.  Edge pads stay inside
    their tile's slab, and their results land in the discarded tail of the
    unsort (positions >= q)."""
    return tuple(jnp.pad(a[order], (0, qpad - a.shape[0]), mode="edge")
                 for a in arrays)


def _tile_base(h0_sorted: jax.Array, tiles: int, cpad: int, *,
               already_sorted: bool) -> jax.Array:
    """Per-tile slab block index, clipped so block s+1 stays in range."""
    t = h0_sorted.reshape(tiles, QT)
    base = (t[:, 0] if already_sorted else t.min(axis=1)) // SLAB
    return jnp.minimum(base.astype(I32), cpad // SLAB - 2)


@partial(jax.jit, static_argnames=("max_probes", "interpret"))
def probe_lookup(tkey: jax.Array, tval: jax.Array, tstate: jax.Array,
                 h0: jax.Array, qkey: jax.Array, *, max_probes: int = 64,
                 interpret: bool = True):
    """Batched linear-probe lookup. Returns (found[Q], val[Q]).

    Args:
      tkey/tval/tstate: table arrays [C].
      h0: start slot per query (hash(key) % C), [Q].
      qkey: query keys [Q].
    """
    c = tkey.shape[0]
    q = qkey.shape[0]
    tk, tv, ts = _pad_table((tkey, tval, tstate), c, max_probes)

    # ONE sort: queries ordered by start slot so tiles hit contiguous slabs
    order = jnp.argsort(h0)
    qpad = -(-q // QT) * QT
    h0s, qks = _sort_pad_queries(order, qpad, h0, qkey)
    tiles = qpad // QT
    slab_base = _tile_base(h0s, tiles, tk.shape[0], already_sorted=True)

    found_s, val_s, complete_s = probe_lookup_tiles(
        tk, tv, ts, h0s, qks, slab_base, max_probes=max_probes,
        interpret=interpret)

    # fallback: recompute incomplete queries with the jnp oracle — gated so
    # the no-skew steady state skips the oracle pass entirely (h0s is already
    # in [0, C), so no re-mod either; the oracle wraps internally).
    need = ~complete_s

    def fallback(fv):
        f0, v0 = fv
        fb_f, fb_v = ref.probe_lookup_ref(tkey, tval, tstate, h0s, qks,
                                          max_probes)
        return jnp.where(need, fb_f, f0), jnp.where(need, fb_v, v0)

    found_s, val_s = jax.lax.cond(need.any(), fallback, lambda fv: fv,
                                  (found_s, val_s))

    # unsort (order permutes [0, q); tail positions are padding)
    found = jnp.zeros((q,), jnp.bool_).at[order].set(found_s[:q])
    val = jnp.zeros((q,), I32).at[order].set(val_s[:q])
    return found, val


@partial(jax.jit, static_argnames=("max_probes", "interpret"))
def ordered_lookup(old_tables, new_tables, hazard_key, hazard_val, hazard_live,
                   h0_old, h0_new, qkey, *, max_probes: int = 64,
                   interpret: bool = True):
    """UNFUSED rebuild-epoch lookup: old table -> hazard buffer -> new table
    (the paper's Lemma 4.1 order), each table pass via its own sort +
    pallas_call.  Kept as the comparison baseline for ``ordered_lookup_fused``
    (see bench_rebuild's fused=on|off axis)."""
    f_old, v_old = probe_lookup(*old_tables, h0_old, qkey,
                                max_probes=max_probes, interpret=interpret)
    eq = (qkey[:, None] == hazard_key[None, :]) & hazard_live[None, :]
    f_hz = eq.any(-1)
    v_hz = jnp.take(hazard_val, jnp.argmax(eq, axis=-1))
    f_new, v_new = probe_lookup(*new_tables, h0_new, qkey,
                                max_probes=max_probes, interpret=interpret)
    found = f_old | f_hz | f_new
    val = jnp.where(f_old, v_old, jnp.where(f_hz, v_hz, v_new))
    return found, val


@partial(jax.jit, static_argnames=("max_probes", "interpret"))
def ordered_lookup_fused(old_tables, new_tables, hazard_key, hazard_val,
                         hazard_live, h0_old, h0_new, qkey, *,
                         max_probes: int = 64, interpret: bool = True):
    """FUSED rebuild-epoch lookup: ONE argsort (keyed on h0_old) and ONE
    pallas_call emit the Lemma-4.1-ordered result for both tables plus the
    hazard buffer.  The new-table slab is anchored per tile at the tile's min
    h0_new; queries whose new-table window escapes it AND that the old table
    / hazard buffer did not resolve fall back to the jnp oracle (gated —
    free when nothing escapes)."""
    c_old = old_tables[0].shape[0]
    c_new = new_tables[0].shape[0]
    q = qkey.shape[0]
    old_p = _pad_table(old_tables, c_old, max_probes)
    new_p = _pad_table(new_tables, c_new, max_probes)

    # the ONE shared sort, keyed on the old table's start slot
    order = jnp.argsort(h0_old)
    qpad = -(-q // QT) * QT
    h0os, h0ns, qks = _sort_pad_queries(order, qpad, h0_old, h0_new, qkey)
    tiles = qpad // QT
    slab2 = jnp.stack([
        _tile_base(h0os, tiles, old_p[0].shape[0], already_sorted=True),
        _tile_base(h0ns, tiles, new_p[0].shape[0], already_sorted=False),
    ])

    found_s, val_s, complete_s = probe2_tiles(
        old_p, new_p, hazard_key, hazard_val, hazard_live.astype(I32),
        h0os, h0ns, qks, slab2, max_probes=max_probes, interpret=interpret)

    need = ~complete_s

    def fallback(fv):
        f0, v0 = fv
        fb_f, fb_v = ref.ordered_lookup_ref(
            old_tables, new_tables, hazard_key, hazard_val, hazard_live,
            h0os, h0ns, qks, max_probes)
        return jnp.where(need, fb_f, f0), jnp.where(need, fb_v, v0)

    found_s, val_s = jax.lax.cond(need.any(), fallback, lambda fv: fv,
                                  (found_s, val_s))

    found = jnp.zeros((q,), jnp.bool_).at[order].set(found_s[:q])
    val = jnp.zeros((q,), I32).at[order].set(val_s[:q])
    return found, val


@partial(jax.jit, static_argnames=("max_probes", "interpret"))
def probe_insert(tkey: jax.Array, tval: jax.Array, tstate: jax.Array,
                 h0: jax.Array, keys: jax.Array, vals: jax.Array,
                 mask: jax.Array, *, max_probes: int = 64,
                 interpret: bool = True):
    """Batched linear-probe INSERT via the claim kernel + one scatter.

    Caller contract: ``mask`` is winner-filtered (at most one True per
    distinct key; use ``buckets.batch_winners``).  Set semantics: ok=False if
    the key is already LIVE or no free slot exists within ``max_probes``.

    Escape hatches (all exact, resolved by the gated jnp fallback):
      * probe window escapes the 2-block slab (``complete=False``);
      * two tiles claim the same physical slot (the padded table holds a
        wrapped copy of the first ``max_probes`` slots, so the same physical
        slot can be claimed under two padded positions) — first claimant in
        sort order keeps it, the loser escapes.

    Returns (tkey', tval', tstate', ok[Q]).
    """
    c = tkey.shape[0]
    q = keys.shape[0]
    tk, ts = _pad_table((tkey, tstate), c, max_probes)

    order = jnp.argsort(h0)
    qpad = -(-q // QT) * QT
    h0s, qks, qvs = _sort_pad_queries(order, qpad, h0, keys, vals)
    qms = _pad_to(mask[order], qpad, fill=False)
    tiles = qpad // QT
    slab_base = _tile_base(h0s, tiles, tk.shape[0], already_sorted=True)

    present_s, claim_s, complete_s = probe_insert_tiles(
        tk, ts, h0s, qks, qms.astype(I32), slab_base,
        max_probes=max_probes, interpret=interpret)

    # resolve claims globally: claims live in padded coordinates within
    # [h0, h0 + max_probes) ⊂ [0, C + max_probes), so % C maps the wrapped
    # region back onto the physical table; first claimant (sort order) wins.
    claimed = complete_s & (claim_s >= 0)
    phys = jnp.where(claimed, claim_s % c, c)
    sidx = jnp.arange(qpad, dtype=I32)
    first = jnp.full((c,), qpad, I32).at[phys].min(sidx, mode="drop")
    keep = claimed & (first[jnp.clip(phys, 0, c - 1)] == sidx)
    conflict = claimed & ~keep

    wp = jnp.where(keep, phys, c)
    tkey2 = tkey.at[wp].set(qks, mode="drop")
    tval2 = tval.at[wp].set(qvs, mode="drop")
    tstate2 = tstate.at[wp].set(LIVE, mode="drop")
    ok_s = keep

    need = qms & (~complete_s | conflict)

    def fallback(op):
        k, v, s, ok = op
        fb_k, fb_v, fb_s, fb_ok = ref.probe_insert_ref(
            k, v, s, h0s, qks, qvs, need, max_probes)
        return fb_k, fb_v, fb_s, ok | fb_ok

    tkey2, tval2, tstate2, ok_s = jax.lax.cond(
        need.any(), fallback, lambda op: op, (tkey2, tval2, tstate2, ok_s))

    ok = jnp.zeros((q,), jnp.bool_).at[order].set(ok_s[:q])
    return tkey2, tval2, tstate2, ok
