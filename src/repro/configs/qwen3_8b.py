"""qwen3-8b [dense]: qk_norm, GQA kv=8 [hf:Qwen/Qwen3-8B; hf].
long_500k SKIPPED (pure full attention)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen3-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=12288, vocab_size=151936,
    qk_norm=True, rope_theta=1_000_000.0,
)

def smoke() -> ArchConfig:
    return CONFIG.scaled(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                         head_dim=16, d_ff=128, vocab_size=512,
                         dtype="float32", attn_chunk=32, loss_chunk=32)
