"""arctic-480b [moe]: 128 experts top-2 PLUS parallel dense-FFN residual
[hf:Snowflake/snowflake-arctic-base; hf]. DHash hash-router enabled (live
rebalancing). long_500k SKIPPED (full attention)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=4864, vocab_size=32000,
    n_experts=128, top_k=2, moe_dff=4864, dense_ff_residual=True,
    use_hash_router=True, fsdp=True,
)

def smoke() -> ArchConfig:
    return CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         head_dim=16, d_ff=128, vocab_size=512,
                         n_experts=8, top_k=2, moe_dff=64,
                         dtype="float32", attn_chunk=32, loss_chunk=32,
                         fsdp=False)
