"""Mamba2 (SSD) block: chunked train path (MXU-friendly matmuls) and O(1)
single-token decode, in the style of the minimal SSD reference.

Chunking keeps all decay terms as exp(L_i - L_j) with i >= j (<= 1, fp32
safe); cross-chunk state is carried by a lax.scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import rms_norm

F32 = jnp.float32


def causal_conv1d(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: [B,S,C], w: [K,C]."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    return sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))


def ssd_chunked(xh: jax.Array, dt: jax.Array, a_log: jax.Array,
                bmat: jax.Array, cmat: jax.Array, *, chunk: int = 128,
                h0: jax.Array | None = None):
    """SSD scan.

    xh:   [B,S,NH,HP]   per-head inputs
    dt:   [B,S,NH]      softplus'd step sizes
    a_log:[NH]          A = -exp(a_log)
    bmat: [B,S,DS]      input projection (n_groups=1, shared across heads)
    cmat: [B,S,DS]      output projection
    Returns y [B,S,NH,HP] and final state [B,NH,DS,HP].
    """
    b, s, nh, hp = xh.shape
    ds = bmat.shape[-1]
    assert s % chunk == 0, (s, chunk)
    n = s // chunk
    a = -jnp.exp(a_log.astype(F32))                       # [NH]
    lam = dt.astype(F32) * a                              # [B,S,NH] log-decay (<=0)

    # reshape to chunks
    def ck(t):
        return t.reshape(b, n, chunk, *t.shape[2:]).swapaxes(0, 1)

    xh_c, dt_c, lam_c = ck(xh), ck(dt.astype(F32)), ck(lam)
    b_c, c_c = ck(bmat), ck(cmat)

    cum = jnp.cumsum(lam_c, axis=2)                       # [n,B,C,NH] inclusive
    if h0 is None:
        h0 = jnp.zeros((b, nh, ds, hp), F32)

    def body(h, inp):
        xc, dtc, lamc, bc, cc, cumc = inp                  # leading dim B
        # intra-chunk: scores[i,j] = (C_i . B_j) * exp(L_i - L_j) * dt_j, i>=j
        cb = jnp.einsum("bis,bjs->bij", cc.astype(F32), bc.astype(F32))  # [B,C,C]
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        diff = cumc[:, :, None, :] - cumc[:, None, :, :]                 # [B,C,C,NH]
        # mask BEFORE exp: the upper triangle would be exp(+large) -> inf,
        # whose cotangent is NaN even under a post-hoc where
        dec = jnp.exp(jnp.where(mask[None, :, :, None], diff, -1e30))
        w = cb[..., None] * dec * dtc[:, None, :, :]                     # [B,i,j,NH]
        y = jnp.einsum("bijh,bjhp->bihp", w, xc.astype(F32))
        # from previous state: y_i += exp(L_i) * C_i @ h
        dec0 = jnp.exp(cumc)                                             # [B,C,NH]
        y += jnp.einsum("bis,bih,bhsp->bihp", cc.astype(F32), dec0, h)
        # state update: h' = exp(L_last) h + sum_j exp(L_last - L_j) dt_j B_j x_j^T
        last = cumc[:, -1:, :]                                           # [B,1,NH]
        decl = jnp.exp(last - cumc)                                      # [B,C,NH]
        h = (jnp.exp(cumc[:, -1, :])[:, :, None, None] * h
             + jnp.einsum("bjs,bjh,bjhp->bhsp", bc.astype(F32),
                          decl * dtc, xc.astype(F32)))
        return h, y

    h, ys = jax.lax.scan(body, h0, (xh_c, dt_c, lam_c, b_c, c_c, cum))
    y = ys.swapaxes(0, 1).reshape(b, s, nh, hp)
    return y.astype(xh.dtype), h


def mamba2_forward(x: jax.Array, p: dict, *, d_inner: int, n_heads: int,
                   headdim: int, d_state: int, conv_k: int, chunk: int = 128):
    """Full mamba2 block. x: [B,S,D]. p holds in_proj/conv_w/a_log/d_skip/
    dt_bias/norm/out_proj. Returns y [B,S,D]."""
    b, s, d = x.shape
    zxbcdt = jnp.einsum("bsd,dp->bsp", x, p["in_proj"])
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, d_inner + d_inner + 2 * d_state], axis=-1)
    xbc = jax.nn.silu(causal_conv1d(xbc, p["conv_w"]).astype(F32)).astype(x.dtype)
    xs, bmat, cmat = jnp.split(xbc, [d_inner, d_inner + d_state], axis=-1)
    dt = jax.nn.softplus(dt.astype(F32) + p["dt_bias"].astype(F32))      # [B,S,NH]
    xh = xs.reshape(b, s, n_heads, headdim)
    y, _ = ssd_chunked(xh, dt, p["a_log"], bmat, cmat, chunk=chunk)
    y = y + xh.astype(F32).astype(x.dtype) * p["d_skip"][None, None, :, None].astype(x.dtype)
    y = y.reshape(b, s, d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(F32)).astype(x.dtype), p["norm"])
    return jnp.einsum("bsp,pd->bsd", y, p["out_proj"])


def mamba2_decode(x1: jax.Array, state: dict, p: dict, *, d_inner: int,
                  n_heads: int, headdim: int, d_state: int, conv_k: int):
    """One-token step. x1: [B,1,D]; state: {"h": [B,NH,DS,HP],
    "conv": [B,K-1,convdim]}. Returns (y1, state')."""
    b, _, d = x1.shape
    zxbcdt = jnp.einsum("bsd,dp->bsp", x1, p["in_proj"])
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, d_inner + d_inner + 2 * d_state], axis=-1)
    xbc = xbc[:, 0]                                       # [B, convdim]
    window = jnp.concatenate([state["conv"], xbc[:, None]], axis=1)  # [B,K,convdim]
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"])
    xbc = jax.nn.silu(conv_out.astype(F32)).astype(x1.dtype)
    xs, bmat, cmat = jnp.split(xbc, [d_inner, d_inner + d_state], axis=-1)
    dt = jax.nn.softplus(dt[:, 0].astype(F32) + p["dt_bias"].astype(F32))  # [B,NH]
    a = -jnp.exp(p["a_log"].astype(F32))
    decay = jnp.exp(dt * a)                               # [B,NH]
    xh = xs.reshape(b, n_heads, headdim).astype(F32)
    h = state["h"] * decay[:, :, None, None] + jnp.einsum(
        "bs,bh,bhp->bhsp", bmat.astype(F32), dt, xh)
    y = jnp.einsum("bs,bhsp->bhp", cmat.astype(F32), h)
    y = y + xh * p["d_skip"][None, :, None].astype(F32)
    y = y.reshape(b, 1, d_inner).astype(x1.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(F32)).astype(x1.dtype), p["norm"])
    y = jnp.einsum("bsp,pd->bsd", y, p["out_proj"])
    state = {"h": h, "conv": window[:, 1:]}
    return y, state


def mamba2_init(key, d_model: int, *, d_inner: int, n_heads: int,
                d_state: int, conv_k: int, dtype) -> dict:
    ks = jax.random.split(key, 4)
    convdim = 2 * d_inner + 2 * d_state  # x + B + C widths: d_inner + 2*ds... see below
    convdim = d_inner + 2 * d_state
    proj_out = 2 * d_inner + 2 * d_state + n_heads
    def init(k, sh, s):
        return (jax.random.normal(k, sh, F32) * s).astype(dtype)
    return {
        "in_proj": init(ks[0], (d_model, proj_out), d_model ** -0.5),
        "conv_w": init(ks[1], (conv_k, convdim), conv_k ** -0.5),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(F32),
        "d_skip": jnp.ones((n_heads,), F32),
        "dt_bias": jnp.zeros((n_heads,), F32),
        "norm": jnp.zeros((d_inner,), dtype),
        "out_proj": init(ks[2], (d_inner, d_model), d_inner ** -0.5),
    }
