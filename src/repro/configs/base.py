"""Architecture configuration schema.

One frozen dataclass describes every supported architecture; per-arch modules
in this package export ``CONFIG`` instances with the exact published
hyper-parameters, plus ``smoke()`` reduced variants for CPU tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]


@dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: Family

    # core dims
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None          # default d_model // n_heads

    # layer pattern: entries cycle to fill n_layers.
    #   "attn"   full-attention block    "local"  sliding-window block
    #   "mamba2" SSD block               "rwkv6"  RWKV time/channel mix
    block_pattern: tuple[str, ...] = ("attn",)
    # hybrid (zamba2): a weight-SHARED attention block is interposed every
    # shared_attn_every scanned blocks (0 = never)
    shared_attn_every: int = 0

    # attention details
    causal: bool = True
    window: int = 4096                   # sliding window for "local" blocks
    attn_softcap: float = 0.0            # gemma2-style tanh cap (0 = off)
    qk_norm: bool = False                # qwen3
    rope_theta: float = 10_000.0
    rope_theta_global: float | None = None   # gemma3: different theta for global
    mrope_sections: tuple[int, int, int] | None = None  # qwen2-vl M-RoPE

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_dff: int = 0                     # expert hidden (arctic: 4864)
    dense_ff_residual: bool = False      # arctic: dense FFN in parallel w/ MoE
    router: Literal["topk", "hash"] = "topk"

    # SSM (mamba2)
    ssm_state: int = 64
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    # rwkv6
    rwkv_head_size: int = 64

    # embeddings / output
    logit_softcap: float = 0.0           # gemma2: 30.0
    tie_embeddings: bool = True
    embed_scale: bool = False            # gemma*: x * sqrt(d_model)
    encoder_only: bool = False           # hubert
    frontend: Literal["tokens", "stub_embed"] = "tokens"  # vlm/audio stubs

    # numerics / memory policy
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"           # "full" | "dots" (save einsum outs)
    attn_chunk: int = 1024               # blockwise-attention query chunk
    loss_chunk: int = 512                # chunked CE seq chunk
    scan_layers: bool = True

    # beyond-paper perf levers (§Perf hillclimbs; default = faithful baseline)
    fused_qkv: bool = False              # one QKV matmul -> one bwd dx AR
    fused_gate_up: bool = False          # one gate|up matmul -> one bwd dx AR
    rwkv_chunk: int = 0                  # 0 = per-step scan; >0 = remat chunks
    rwkv_tp_state: str = ""              # "" | "value" | "replicated" (§Perf)
    rwkv_fused_rkvg: bool = False        # one stacked r/k/v/g matmul (§Perf)

    # distribution policy
    fsdp: bool = False                   # shard big weight dims over "data" too

    # DHash integration
    use_hash_router: bool = False        # MoE archs: DHash-backed hash routing
    paged_kv: bool = True                # serving: DHash page-table indirection

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ---- shape helpers -----------------------------------------------------
    @property
    def blocks(self) -> tuple[str, ...]:
        """Full per-layer kind list of length n_layers."""
        p = self.block_pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    def scaled(self, **overrides) -> "ArchConfig":
        return dataclasses.replace(self, **overrides)

    # parameter count (embedding + blocks), for 6ND model-flops accounting
    def param_count(self, active_only: bool = False) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        n_q, n_kv = self.n_heads, self.n_kv_heads
        per_block = {}
        attn = d * hd * (n_q + 2 * n_kv) + n_q * hd * d
        mlp = 3 * d * f
        per_block["attn"] = attn + mlp + 2 * d
        per_block["local"] = per_block["attn"]
        if self.n_experts:
            e = self.top_k if active_only else self.n_experts
            moe = e * 3 * d * self.moe_dff
            if self.dense_ff_residual:
                moe += 3 * d * f
            router = d * self.n_experts
            per_block["attn"] = attn + moe + router + 2 * d
            per_block["local"] = per_block["attn"]
        d_in = self.ssm_expand * d
        per_block["mamba2"] = (d * (2 * d_in + 2 * self.ssm_state + d_in // self.ssm_headdim)
                               + d_in * d + 2 * d)
        per_block["rwkv6"] = d * d * 4 + d * f * 2 + 2 * d  # r,k,v,o + channel-mix
        total = sum(per_block[k] for k in self.blocks)
        if self.shared_attn_every:
            total += attn + mlp + 2 * d
        total += v * d * (1 if self.tie_embeddings else 2)
        return int(total)
