"""Distributed DHash: routed ops on an 8-device host mesh (subprocess, so
the 8-device XLA flag never leaks into other tests)."""
from __future__ import annotations

import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
import jax.tree_util as jtu
from functools import partial
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core import dhash, distributed as dd, hashing

# jax >= 0.6 exposes jax.shard_map (check_vma); 0.4/0.5 ship it under
# jax.experimental.shard_map with the older check_rep spelling
if hasattr(jax, "shard_map"):
    shard_map, _smap_kw = jax.shard_map, {"check_vma": False}
else:
    from jax.experimental.shard_map import shard_map
    _smap_kw = {"check_rep": False}

mesh = jax.sharding.Mesh(np.array(jax.devices()).reshape(8), ("model",))
owner = hashing.fresh("tabulation", 7)
stacked = dd.make_stacked(8, "linear", capacity=256, chunk=64, seed=0)
tspec = jtu.tree_map(lambda _: P("model"), dhash.make("linear", 256, chunk=64))
stacked = jtu.tree_map(
    lambda x: jax.device_put(x, NamedSharding(mesh, P("model"))), stacked)

keys = jnp.arange(1, 513, dtype=jnp.int32)
vals = keys * 3

@partial(shard_map, mesh=mesh, **_smap_kw,
         in_specs=(tspec, P("model"), P("model"), P("model"), P("model")),
         out_specs=(tspec, P("model")))
def service(dstack, lk, ik, iv, dk):
    d = dd.peel(dstack)
    d, (found, _, stats) = dd.routed_service_step(d, lk, ik, iv, dk, "model", owner)
    return dd.unpeel(d), stats[None]

# step 1: insert everything (lookups miss), step 2: all lookups hit
z = jnp.zeros((8,), jnp.int32)
stacked, stats = jax.jit(service)(stacked, keys, keys, vals, z)
stacked, stats = jax.jit(service)(stacked, keys, z, z, z)
found_total = int(np.asarray(stats)[:, 0].sum())
assert found_total == 512, found_total

# capped routing agrees with uncapped under uniform keys
@partial(shard_map, mesh=mesh, **_smap_kw,
         in_specs=(tspec, P("model")), out_specs=(P("model"), P("model")))
def lookup_capped(dstack, lk):
    d = dd.peel(dstack)
    f, v = dd.routed_lookup(d, lk, "model", owner, cap=lk.shape[0] // 2)
    return f, v

f, v = jax.jit(lookup_capped)(stacked, keys)
f, v = np.asarray(f), np.asarray(v)
assert f.sum() >= 500, f.sum()        # a few may exceed per-owner cap
assert (v[f] == np.asarray(keys)[f] * 3).all()

# shard-local rebuild with synchronized epochs: all data survives
for _ in range(64):
    stacked, _ = jax.jit(service)(stacked, z, z, z, z)  # rebuild_step x64

print("DIST-OK")
"""


def test_distributed_dhash_8dev():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-3000:]
    assert "DIST-OK" in r.stdout
