"""End-to-end driver: train a ~100M-parameter hash-routed MoE for a few
hundred steps, with the DHash router override table rebalancing live.

    PYTHONPATH=src python examples/train_hash_moe.py [--steps 300]

This is the framework's training path end-to-end: deterministic data
pipeline -> scan-over-layers model -> AdamW -> checkpoints, with the paper's
technique in the routing hot path (expert-load skew triggers a live DHash
rebuild; training never pauses).
"""
import argparse
import time
from functools import partial

import jax
import numpy as np

from repro.configs.base import ArchConfig
from repro.data.pipeline import DataConfig, synth_batch
from repro.optim.optimizer import OptConfig
from repro.train import train_step as ts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    # ~100M params: 8 layers, d=512, 16 experts top-1 hash-routed
    cfg = ArchConfig(
        arch_id="moe-100m", family="moe", n_layers=8, d_model=512,
        n_heads=8, n_kv_heads=4, d_ff=1536, vocab_size=32_000,
        n_experts=16, top_k=1, moe_dff=1024, use_hash_router=True,
        dtype="float32", attn_chunk=128, loss_chunk=128)
    print(f"params: {cfg.param_count()/1e6:.1f}M "
          f"(active {cfg.param_count(active_only=True)/1e6:.1f}M)")

    opt_cfg = OptConfig(lr=1e-3, total_steps=args.steps,
                        warmup_steps=args.steps // 20)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch, seed=0, zipf_a=1.1)
    state = ts.init_state(cfg, opt_cfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(partial(ts.train_step, cfg=cfg, opt_cfg=opt_cfg),
                      donate_argnums=0)

    t0 = time.time()
    for step in range(args.steps):
        state, m = step_fn(state, synth_batch(dcfg, step))
        # live router rebalancing on observed skew (the paper's response)
        state = ts.rebalance_router(state, m["expert_load"], cfg, hot_frac=1.5)
        if step % 25 == 0 or step == args.steps - 1:
            load = np.asarray(jax.device_get(m["expert_load"]))
            imb = load.max() / max(load.mean(), 1)
            print(f"step {step:4d} loss {float(m['loss']):.4f} "
                  f"expert-imbalance {imb:.2f} "
                  f"router-rebuilding={bool(jax.device_get(state['router_table'].rebuilding))}")
    dt = time.time() - t0
    toks = args.steps * args.batch * args.seq
    print(f"done: {toks/dt:.0f} tok/s over {dt:.0f}s")


if __name__ == "__main__":
    main()
