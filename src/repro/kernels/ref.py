"""Pure-jnp oracles for the Pallas kernels (the ground truth in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hashing

I32 = jnp.int32
EMPTY, LIVE, TOMB, MIGRATED = 0, 1, 2, 3


def probe_lookup_ref(tkey: jax.Array, tval: jax.Array, tstate: jax.Array,
                     h0: jax.Array, qkey: jax.Array, max_probes: int):
    """Linear-probe lookup oracle.

    Probes slots h0, h0+1, ... (mod C): stop on LIVE match (found) or EMPTY
    (absent); skip TOMB/MIGRATED.  Returns (found[Q] bool, val[Q] i32).
    """
    c = tkey.shape[0]
    q = qkey.shape[0]

    def body(i, carry):
        active, found, val = carry
        pos = (h0 + i) % c
        st = tstate[pos]
        hit = active & (st == LIVE) & (tkey[pos] == qkey)
        stop = active & (st == EMPTY)
        val = jnp.where(hit, tval[pos], val)
        found = found | hit
        active = active & ~hit & ~stop
        return active, found, val

    init = (jnp.ones((q,), bool), jnp.zeros((q,), bool), jnp.zeros((q,), I32))
    _, found, val = jax.lax.fori_loop(0, max_probes, body, init)
    return found, val


def probe_insert_ref(tkey: jax.Array, tval: jax.Array, tstate: jax.Array,
                     h0: jax.Array, keys: jax.Array, vals: jax.Array,
                     mask: jax.Array, max_probes: int):
    """Linear-probe insert oracle on raw table arrays (claim-first-non-LIVE,
    lowest batch index wins a contested slot — the same linearization as
    ``buckets.linear_insert``).

    Caller contract: ``mask`` is winner-filtered (at most one True per
    distinct key; use ``buckets.batch_winners``).  Returns
    (tkey', tval', tstate', ok[Q]).
    """
    c = tkey.shape[0]
    q = keys.shape[0]
    present, _ = probe_lookup_ref(tkey, tval, tstate, h0, keys, max_probes)
    pending0 = mask & ~present
    idx = jnp.arange(q, dtype=I32)

    def body(p, carry):
        key, val, state, pending, done = carry
        pos = (h0 + p) % c
        free = pending & (state[pos] != LIVE)
        wpos = jnp.where(free, pos, c)
        claim = jnp.full((c,), q, I32).at[wpos].min(idx, mode="drop")
        won = free & (claim[pos] == idx)
        wp = jnp.where(won, pos, c)
        key = key.at[wp].set(keys, mode="drop")
        val = val.at[wp].set(vals, mode="drop")
        state = state.at[wp].set(LIVE, mode="drop")
        return key, val, state, pending & ~won, done | won

    init = (tkey, tval, tstate, pending0, jnp.zeros((q,), bool))
    tkey, tval, tstate, _, done = jax.lax.fori_loop(0, max_probes, body, init)
    return tkey, tval, tstate, done


def ordered_lookup_ref(old_t, new_t, hazard_key, hazard_val, hazard_live,
                       h0_old, h0_new, qkey, max_probes: int):
    """The paper's ordered three-way check: old -> hazard -> new."""
    f_old, v_old = probe_lookup_ref(*old_t, h0_old, qkey, max_probes)
    eq = (qkey[:, None] == hazard_key[None, :]) & hazard_live[None, :]
    f_hz = eq.any(-1)
    v_hz = jnp.take(hazard_val, jnp.argmax(eq, axis=-1))
    f_new, v_new = probe_lookup_ref(*new_t, h0_new, qkey, max_probes)
    found = f_old | f_hz | f_new
    val = jnp.where(f_old, v_old, jnp.where(f_hz, v_hz, v_new))
    return found, val


def probe_delete_ref(tkey: jax.Array, tval: jax.Array, tstate: jax.Array,
                     h0: jax.Array, keys: jax.Array, mask: jax.Array,
                     max_probes: int):
    """Linear-probe delete oracle: tombstone the LIVE slot holding each
    masked key (probe from h0, skip TOMB/MIGRATED, stop at EMPTY).

    Caller contract: ``mask`` is winner-filtered (at most one True per
    distinct key).  Returns (tstate', ok[Q]).
    """
    c = tkey.shape[0]
    q = keys.shape[0]

    def body(i, carry):
        active, found, loc = carry
        pos = (h0 + i) % c
        st = tstate[pos]
        hit = active & (st == LIVE) & (tkey[pos] == keys)
        stop = active & (st == EMPTY)
        loc = jnp.where(hit, pos, loc)
        found = found | hit
        active = active & ~hit & ~stop
        return active, found, loc

    init = (jnp.ones((q,), bool), jnp.zeros((q,), bool),
            jnp.full((q,), -1, I32))
    _, found, loc = jax.lax.fori_loop(0, max_probes, body, init)
    ok = mask & found
    tstate = tstate.at[jnp.where(ok, loc, c)].set(TOMB, mode="drop")
    return tstate, ok


def tc_row_lookup_ref(tkey: jax.Array, tval: jax.Array, tstate: jax.Array,
                      rows: jax.Array, qkey: jax.Array):
    """Single-row twochoice lookup oracle: gather row ``rows[e]`` and match
    all W lanes.  Returns (found[E], val[E], loc[E] flat slot or -1)."""
    w = tkey.shape[1]
    krow, vrow, srow = tkey[rows], tval[rows], tstate[rows]   # [E, W]
    hit = (krow == qkey[:, None]) & (srow == LIVE)
    found = hit.any(-1)
    lane = jnp.argmax(hit, axis=-1)
    val = jnp.take_along_axis(vrow, lane[:, None], axis=-1)[:, 0]
    return (found, jnp.where(found, val, 0),
            jnp.where(found, rows * w + lane.astype(I32), -1))


def tc_insert_ref(tkey: jax.Array, tval: jax.Array, tstate: jax.Array,
                  rows_a: jax.Array, rows_b: jax.Array, keys: jax.Array,
                  vals: jax.Array, mask: jax.Array, max_rounds: int):
    """Twochoice insert oracle on raw [B, W] arrays: alternate the two row
    choices per round, claim the row's first non-LIVE lane, lowest batch
    index wins a contested lane (same linearization as
    ``buckets.twochoice_insert``).

    Caller contract: ``mask`` is winner-filtered.  Returns
    (tkey', tval', tstate', ok[Q]).
    """
    b, w = tkey.shape
    q = keys.shape[0]
    fa, _, _ = tc_row_lookup_ref(tkey, tval, tstate, rows_a, keys)
    fb, _, _ = tc_row_lookup_ref(tkey, tval, tstate, rows_b, keys)
    pending0 = mask & ~(fa | fb)
    idx = jnp.arange(q, dtype=I32)
    nslots = b * w

    def body(r, carry):
        key, val, state, pending, done = carry
        bkt = jnp.where(r % 2 == 0, rows_a, rows_b)
        row_free = state[bkt] != LIVE                       # [Q, W]
        has_free = pending & row_free.any(-1)
        lane = jnp.argmax(row_free, axis=-1)
        flat = bkt * w + lane.astype(I32)
        wflat = jnp.where(has_free, flat, nslots)
        claim = jnp.full((nslots,), q, I32).at[wflat].min(idx, mode="drop")
        won = has_free & (claim[flat % nslots] == idx) & (wflat < nslots)
        wp = jnp.where(won, flat, nslots)
        key = key.reshape(-1).at[wp].set(keys, mode="drop").reshape(b, w)
        val = val.reshape(-1).at[wp].set(vals, mode="drop").reshape(b, w)
        state = state.reshape(-1).at[wp].set(LIVE, mode="drop").reshape(b, w)
        return key, val, state, pending & ~won, done | won

    init = (tkey, tval, tstate, pending0, jnp.zeros((q,), bool))
    tkey, tval, tstate, _, done = jax.lax.fori_loop(0, max_rounds, body, init)
    return tkey, tval, tstate, done


def chain_lookup_ref(akey: jax.Array, aval: jax.Array, astate: jax.Array,
                     anext: jax.Array, heads: jax.Array, b: jax.Array,
                     qkey: jax.Array, max_chain: int):
    """Pointer-chasing chain lookup oracle: lock-step batched traversal from
    ``heads[b]`` along ``anext``, bounded by ``max_chain`` hops — each hop is
    one dependent arena gather (the CPU cost model the arena-sorted fused
    path exists to avoid).  Returns (found[Q], val[Q], loc[Q] node or -1).
    """
    q = qkey.shape[0]

    def body(_, carry):
        cur, found, val, loc = carry
        valid = cur >= 0
        c = jnp.where(valid, cur, 0)
        hit = valid & (astate[c] == LIVE) & (akey[c] == qkey) & ~found
        val = jnp.where(hit, aval[c], val)
        loc = jnp.where(hit, cur, loc)
        found = found | hit
        step = valid & ~found
        cur = jnp.where(step, anext[c], jnp.where(found, cur, -1))
        return cur, found, val, loc

    init = (heads[b], jnp.zeros((q,), bool), jnp.zeros((q,), I32),
            jnp.full((q,), -1, I32))
    _, found, val, loc = jax.lax.fori_loop(0, max_chain, body, init)
    return found, val, loc


def chain_delete_ref(akey: jax.Array, aval: jax.Array, astate: jax.Array,
                     anext: jax.Array, heads: jax.Array, b: jax.Array,
                     keys: jax.Array, mask: jax.Array, max_chain: int):
    """Pointer-chasing chain delete oracle: traverse, then tombstone the
    node holding each masked key (logical deletion; reclamation is the
    compaction pass).  Caller contract: mask winner-filtered.  Returns
    (astate', ok[Q])."""
    n = akey.shape[0]
    found, _, loc = chain_lookup_ref(akey, aval, astate, anext, heads, b,
                                     keys, max_chain)
    ok = mask & found
    astate = astate.at[jnp.where(ok, loc, n)].set(TOMB, mode="drop")
    return astate, ok


def chain_insert_ref(akey, aval, astate, anext, heads, free_stack, free_top,
                     b, keys, vals, mask, max_chain: int):
    """Pointer-chasing chain insert oracle on raw arena arrays: presence by
    lock-step traversal, want-rank tail allocation, insert-at-head linking
    in original-index order — the same linearization, node placement, and
    pointer structure as ``buckets.chain_insert``.

    Caller contract: ``mask`` is winner-filtered.  Returns
    (akey', aval', astate', anext', heads', free_top', ok[Q]).
    """
    n = akey.shape[0]
    nb = heads.shape[0]
    q = keys.shape[0]
    present, _, _ = chain_lookup_ref(akey, aval, astate, anext, heads, b,
                                     keys, max_chain)
    want = mask & ~present
    rank = jnp.cumsum(want.astype(I32)) - 1
    can = want & (rank < free_top)
    node = free_stack[jnp.where(can, free_top - 1 - rank, 0)]
    wnode = jnp.where(can, node, n)
    akey = akey.at[wnode].set(keys, mode="drop")
    aval = aval.at[wnode].set(vals, mode="drop")
    astate = astate.at[wnode].set(LIVE, mode="drop")
    idx = jnp.arange(q, dtype=I32)
    sortkey = jnp.where(can, b, nb)
    order = jnp.lexsort((idx, sortkey))
    sb, snode, scan = sortkey[order], node[order], can[order]
    nxt_same = jnp.concatenate([snode[1:], jnp.full((1,), -1, I32)])
    same_bucket = jnp.concatenate([sb[1:] == sb[:-1], jnp.zeros((1,), bool)])
    old_head = heads[jnp.where(scan, sb, 0)]
    nxt = jnp.where(same_bucket, nxt_same, jnp.where(scan, old_head, -1))
    anext = anext.at[jnp.where(scan, snode, n)].set(nxt, mode="drop")
    is_start = jnp.concatenate([jnp.ones((1,), bool), sb[1:] != sb[:-1]])
    heads = heads.at[jnp.where(scan & is_start, sb, nb)].set(snode,
                                                             mode="drop")
    free_top = free_top - jnp.sum(can.astype(I32))
    return akey, aval, astate, anext, heads, free_top, can


def chain_ordered_lookup_ref(old_arena, old_links, new_arena, new_links,
                             hazard_key, hazard_val, hazard_live,
                             b_old, b_new, qkey, max_chain: int):
    """The paper's ordered three-way check over chained tables:
    old chains -> hazard buffer -> new chains."""
    f_old, v_old, _ = chain_lookup_ref(*old_arena, *old_links, b_old, qkey,
                                       max_chain)
    eq = (qkey[:, None] == hazard_key[None, :]) & hazard_live[None, :]
    f_hz = eq.any(-1)
    v_hz = jnp.take(hazard_val, jnp.argmax(eq, axis=-1))
    f_new, v_new, _ = chain_lookup_ref(*new_arena, *new_links, b_new, qkey,
                                       max_chain)
    found = f_old | f_hz | f_new
    val = jnp.where(f_old, v_old, jnp.where(f_hz, v_hz, v_new))
    return found, val


def tc_delete_ref(tkey: jax.Array, tval: jax.Array, tstate: jax.Array,
                  rows_a: jax.Array, rows_b: jax.Array, keys: jax.Array,
                  mask: jax.Array):
    """Twochoice delete oracle: tombstone the LIVE lane holding each masked
    key in either row.  Caller contract: mask winner-filtered.  Returns
    (tstate', ok[Q])."""
    b, w = tkey.shape
    fa, _, la = tc_row_lookup_ref(tkey, tval, tstate, rows_a, keys)
    fb, _, lb = tc_row_lookup_ref(tkey, tval, tstate, rows_b, keys)
    ok = mask & (fa | fb)
    loc = jnp.where(fa, la, lb)
    tstate = tstate.reshape(-1).at[jnp.where(ok, loc, b * w)].set(
        TOMB, mode="drop").reshape(b, w)
    return tstate, ok


def cuckoo_kick_ref(tkey: jax.Array, tval: jax.Array, tstate: jax.Array,
                    rows_a: jax.Array, rows_b: jax.Array,
                    hfn_a, hfn_b, nbuckets: int,
                    keys: jax.Array, vals: jax.Array, pending: jax.Array,
                    max_kick: int):
    """Batched bounded kick-out over the cuckoo table's [2B, W] rows
    (side A rows [0, B), side B rows [B, 2B); ``rows_a``/``rows_b`` are the
    two candidate rows of each query, already side-offset).

    Runs exactly ``max_kick`` fixed iterations (the MAX_KICK_OUT idiom of
    SNIPPETS.md snippet 1, rendered as a batched fori_loop).  Per iteration
    each still-pending query forms one of two plans:

    * **plan A** — either candidate row has a free lane: claim it (prefer
      the a-row, matching the insert tie-break);
    * **plan B** — both rows full: pick a victim lane whose occupant's
      ALTERNATE row (the other side, under the other hash function)
      currently has a free lane, move the victim there, and take its lane.
      The candidate scan order is rotated by the iteration index so two
      queries fighting over the same rows do not ping-pong on one victim.

    Arbitration is per-ROW: a scatter-min lock over all 2B rows (lowest
    batch index wins); a query executes only if it owns every row its plan
    touches (one row for plan A, victim row + alternate row for plan B).
    Losers simply retry next iteration — the conflict-escape.  Because a
    resident entry only ever moves INTO a directly-free lane of its own
    alternate row, no resident is ever evicted without a landing slot: on
    kick exhaustion only the NEW key reports ok=False.

    Caller contract: ``pending`` is winner-filtered and presence-checked.
    Returns (tkey', tval', tstate', done[Q]).
    """
    b2, w = tkey.shape
    q = keys.shape[0]
    idx = jnp.arange(q, dtype=I32)
    lane_ids = jnp.arange(2 * w, dtype=I32)
    nslots = b2 * w

    def body(it, carry):
        key, val, state, pend, done = carry
        sa, sb = state[rows_a], state[rows_b]              # [Q, W]
        free_a, free_b = (sa != LIVE).any(-1), (sb != LIVE).any(-1)

        # plan A: direct claim of a free lane (a-row priority)
        plan_a = pend & (free_a | free_b)
        row_a_tgt = jnp.where(free_a, rows_a, rows_b)
        tgt_free = state[row_a_tgt] != LIVE                # [Q, W]
        lane_a = jnp.argmax(tgt_free, axis=-1)

        # plan B: move a victim whose alternate row has a free lane.
        # victim candidates are the 2W lanes (a-row lanes then b-row lanes);
        # a victim parked in side A relocates to B + hb(victim), side B to
        # ha(victim) — always the other side, so victim row != alt row.
        vrow = jnp.concatenate([
            jnp.broadcast_to(rows_a[:, None], (q, w)),
            jnp.broadcast_to(rows_b[:, None], (q, w))], axis=-1)  # [Q, 2W]
        vkey = key[vrow, lane_ids % w]                     # [Q, 2W]
        alt_a = nbuckets + hashing.bucket_of(hfn_b, vkey, nbuckets)
        alt_b = hashing.bucket_of(hfn_a, vkey, nbuckets)
        valt = jnp.where(lane_ids[None, :] < w, alt_a, alt_b)
        cand = (state[vrow, lane_ids % w] == LIVE) \
            & (state[valt] != LIVE).any(-1)                # [Q, 2W]
        rot = (lane_ids + it) % (2 * w)
        sel = rot[jnp.argmax(jnp.take_along_axis(
            cand, jnp.broadcast_to(rot[None, :], (q, 2 * w)), axis=-1),
            axis=-1)]
        plan_b = pend & ~plan_a & cand.any(-1)
        b_vrow = jnp.take_along_axis(vrow, sel[:, None], axis=-1)[:, 0]
        b_valt = jnp.take_along_axis(valt, sel[:, None], axis=-1)[:, 0]
        b_vlane = sel % w
        b_vkey = jnp.take_along_axis(vkey, sel[:, None], axis=-1)[:, 0]

        # per-row locks: a query owns a row iff it wins the scatter-min on
        # it; plan A needs its target row, plan B both victim + alt rows
        lock = jnp.full((b2,), q, I32)
        lock = lock.at[jnp.where(plan_a, row_a_tgt, b2)].min(idx, mode="drop")
        lock = lock.at[jnp.where(plan_b, b_vrow, b2)].min(idx, mode="drop")
        lock = lock.at[jnp.where(plan_b, b_valt, b2)].min(idx, mode="drop")
        own_a = plan_a & (lock[row_a_tgt % b2] == idx)
        own_b = plan_b & (lock[b_vrow % b2] == idx) & (lock[b_valt % b2] == idx)

        # plan B execution: victim lands in its alternate row's first free
        # lane, then the new key takes the vacated lane
        alt_lane = jnp.argmax(state[b_valt] != LIVE, axis=-1)
        b_vval = val[b_vrow, b_vlane]
        mv = jnp.where(own_b, b_valt * w + alt_lane, nslots)
        key = key.reshape(-1).at[mv].set(b_vkey, mode="drop")
        val = val.reshape(-1).at[mv].set(b_vval, mode="drop")
        state = state.reshape(-1).at[mv].set(LIVE, mode="drop")

        won = own_a | own_b
        wp = jnp.where(own_a, row_a_tgt * w + lane_a,
                       jnp.where(own_b, b_vrow * w + b_vlane, nslots))
        key = key.at[wp].set(keys, mode="drop").reshape(b2, w)
        val = val.at[wp].set(vals, mode="drop").reshape(b2, w)
        state = state.at[wp].set(LIVE, mode="drop").reshape(b2, w)
        return key, val, state, pend & ~won, done | won

    init = (tkey, tval, tstate, pending, jnp.zeros((q,), bool))
    tkey, tval, tstate, _, done = jax.lax.fori_loop(0, max_kick, body, init)
    return tkey, tval, tstate, done
