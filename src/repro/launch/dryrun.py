import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: sharding
mismatches, compile-time OOM and unsupported collectives all fail HERE.
Roofline terms (EXPERIMENTS.md §Roofline) are derived from each cell's
compiled artifact.

Usage:
  python -m repro.launch.dryrun                      # all cells, both meshes
  python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k --mesh single
  python -m repro.launch.dryrun --list
Results land in benchmarks/results/dryrun/<mesh>_<arch>_<shape>.json.
"""

import argparse
import json
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import ArchConfig
from repro.launch import analysis, shapes as shp
from repro.launch.mesh import make_production_mesh
from repro.optim.optimizer import OptConfig

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")


def _opt_cfg() -> OptConfig:
    return OptConfig(total_steps=10_000)


def lower_train(cfg: ArchConfig, sp: shp.ShapeSpec, mesh):
    from repro.models.sharding import activation_ctx
    from repro.train import train_step as ts
    state, sshard = shp.state_struct(cfg, mesh, _opt_cfg())
    batch, bshard = shp.batch_struct(cfg, sp, mesh)
    fn = partial(ts.train_step, cfg=cfg, opt_cfg=_opt_cfg())
    with mesh, activation_ctx(mesh):
        jitted = jax.jit(fn, in_shardings=(sshard, bshard),
                         donate_argnums=0)
        return jitted.lower(state, batch)


def lower_prefill(cfg: ArchConfig, sp: shp.ShapeSpec, mesh):
    from repro.models import model, transformer, sharding as shard_lib

    def prefill(params, batch):
        hidden, _ = transformer.forward_train(params, cfg, batch)
        w = transformer.unembed_matrix(params, cfg)
        logits = jnp.einsum("bd,dv->bv", hidden[:, -1], w)
        return logits

    params = jax.eval_shape(partial(transformer.init_params, cfg),
                            jax.random.PRNGKey(0))
    pshard = shard_lib.param_shardings(params, mesh, fsdp=cfg.fsdp)
    batch, bshard = shp.batch_struct(cfg, sp, mesh)
    batch.pop("labels"), bshard.pop("labels")
    with mesh, shard_lib.activation_ctx(mesh):
        jitted = jax.jit(prefill, in_shardings=(pshard, bshard))
        return jitted.lower(params, batch)


def lower_decode(cfg: ArchConfig, sp: shp.ShapeSpec, mesh):
    from repro.models import model, transformer, sharding as shard_lib

    def serve_step(params, cache, tok):
        return model.decode_logits(params, cfg, tok, cache)

    params = jax.eval_shape(partial(transformer.init_params, cfg),
                            jax.random.PRNGKey(0))
    pshard = shard_lib.param_shardings(params, mesh, fsdp=cfg.fsdp)
    cache, cshard = shp.cache_struct(cfg, sp, mesh)
    tok, tshard = shp.decode_inputs(cfg, sp, mesh)
    with mesh, shard_lib.activation_ctx(mesh):
        jitted = jax.jit(serve_step, in_shardings=(pshard, cshard, tshard),
                         donate_argnums=1)
        return jitted.lower(params, cache, tok)


def lower_dhash_service(mesh, scfg=None):
    """The paper's own workload on the production mesh: a model-axis-sharded
    DHash service step (routed lookups/updates + one rebuild transition)."""
    import jax.tree_util as jtu
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import dhash, distributed as dd, hashing

    scfg = scfg or configs.get_config("dhash-paper")
    nshards = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    owner = hashing.fresh("tabulation", 7)
    d0 = dhash.make(scfg.backend, scfg.capacity_per_shard, chunk=scfg.chunk,
                    seed=0, fwd_hazard=getattr(scfg, "fwd_hazard", False))
    stacked = jtu.tree_map(
        lambda x: jax.ShapeDtypeStruct((nshards,) + x.shape, x.dtype), d0)
    tspec = jtu.tree_map(lambda _: P("model"), d0)
    q, u = scfg.lookups_per_step, scfg.updates_per_step
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    keys = {
        "lk": jax.ShapeDtypeStruct((nshards * q,), jnp.int32),
        "ik": jax.ShapeDtypeStruct((nshards * u,), jnp.int32),
        "iv": jax.ShapeDtypeStruct((nshards * u,), jnp.int32),
        "dk": jax.ShapeDtypeStruct((nshards * u,), jnp.int32),
    }

    @partial(jax.shard_map, mesh=mesh, check_vma=False,
             in_specs=(tspec, P("model"), P("model"), P("model"), P("model")),
             out_specs=(tspec, P("model")))
    def service(dstack, lk, ik, iv, dk):
        d = dd.peel(dstack)
        d, (found, vals, stats) = dd.routed_service_step(
            d, lk, ik, iv, dk, "model", owner,
            cap_factor=scfg.route_cap_factor)
        return dd.unpeel(d), stats[None]

    with mesh:
        jitted = jax.jit(service,
                         in_shardings=(jtu.tree_map(lambda s: NamedSharding(mesh, s), tspec),
                                       *(NamedSharding(mesh, P("model")),) * 4),
                         donate_argnums=0)
        return jitted.lower(stacked, keys["lk"], keys["ik"], keys["iv"], keys["dk"])


def run_cell(arch: str, shape: str, mesh_kind: str, *, save: bool = True) -> dict:
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    chips = int(mesh.devices.size)
    t0 = time.time()
    rec: dict = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                 "chips": chips}

    if arch == "dhash-paper":
        lowered = lower_dhash_service(mesh)
        model_flops = 0.0
        sp = None
    else:
        cfg = configs.get_config(arch)
        sp = shp.SHAPES[shape]
        skip = shp.applicability(cfg, shape)
        if skip:
            rec |= {"status": "skip", "reason": skip}
            if save:
                _save(rec)
            return rec
        lower = {"train": lower_train, "prefill": lower_prefill,
                 "decode": lower_decode}[sp.kind]
        lowered = lower(cfg, sp, mesh)
        n = cfg.param_count(active_only=True)
        if sp.kind == "train":
            tokens = sp.global_batch * sp.seq_len
            model_flops = 6 * n * tokens
        elif sp.kind == "prefill":
            tokens = sp.global_batch * sp.seq_len
            model_flops = 2 * n * tokens
        else:
            model_flops = 2 * n * sp.global_batch     # one token per seq

    rec["lower_s"] = round(time.time() - t0, 1)
    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 1)

    # trip-count-aware per-chip HLO walk (xla cost_analysis does not
    # multiply while bodies; see hlo_cost.py) - shapes are per-device, so
    # walker numbers are per-chip; roofline divides global model_flops.
    from repro.launch import hlo_cost
    hlo = compiled.as_text()
    cost = hlo_cost.analyze(hlo)
    raw_flops, raw_bytes = analysis.cost_of(compiled)
    mem = analysis.memory_of(compiled)
    rl = analysis.Roofline(chips=chips, hlo_flops=cost.flops * chips,
                           hlo_bytes=cost.bytes * chips,
                           coll_bytes=cost.coll_bytes * chips,
                           model_flops=model_flops)
    rec |= {"status": "ok",
            "cost": {"flops_per_chip": cost.flops, "bytes_per_chip": cost.bytes,
                     "coll_bytes_per_chip": cost.coll_bytes,
                     "coll_detail": cost.coll, "coll_counts": cost.coll_counts,
                     "xla_raw_flops": raw_flops, "xla_raw_bytes": raw_bytes},
            "top_bytes": cost.top_bytes(10),
            "memory": mem, "roofline": rl.to_dict()}
    if save:
        _save(rec)
    return rec


def _save(rec: dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR,
                        f"{rec['mesh']}_{rec['arch']}_{rec['shape']}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=float)


def all_cells() -> list[tuple[str, str]]:
    cells = [(a, s) for a in configs.ARCH_IDS for s in shp.SHAPES]
    cells.append(("dhash-paper", "service"))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = all_cells()
    if args.arch:
        cells = [c for c in cells if c[0] == args.arch]
    if args.shape:
        cells = [c for c in cells if c[1] == args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.list:
        for a, s in cells:
            print(a, s)
        return

    failures = []
    for a, s in cells:
        for mk in meshes:
            tag = f"{mk:6s} {a:24s} {s}"
            out = os.path.join(RESULTS_DIR, f"{mk}_{a}_{s}.json")
            if args.skip_existing and os.path.exists(out):
                print(f"[cached] {tag}")
                continue
            try:
                rec = run_cell(a, s, mk)
                if rec["status"] == "skip":
                    print(f"[ skip ] {tag}: {rec['reason']}")
                else:
                    rl = rec["roofline"]
                    print(f"[  ok  ] {tag}: {rec['compile_s']:.0f}s compile, "
                          f"bottleneck={rl['bottleneck']}, "
                          f"step={rl['step_time']*1e3:.1f}ms, mfu={rl['mfu']:.2f}")
            except Exception as e:
                failures.append((a, s, mk, repr(e)))
                print(f"[ FAIL ] {tag}: {e!r}")
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run cells failed: "
                         + "; ".join(f"{a}/{s}/{m}" for a, s, m, _ in failures))
    print("ALL DRY-RUN CELLS PASSED")


if __name__ == "__main__":
    main()
