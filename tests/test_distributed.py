"""Distributed DHash: routed ops on an 8-device host mesh (subprocess, so
the 8-device XLA flag never leaks into other tests)."""
from __future__ import annotations

import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
import jax.tree_util as jtu
from functools import partial
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core import dhash, distributed as dd, hashing

# jax >= 0.6 exposes jax.shard_map (check_vma); 0.4/0.5 ship it under
# jax.experimental.shard_map with the older check_rep spelling
if hasattr(jax, "shard_map"):
    shard_map, _smap_kw = jax.shard_map, {"check_vma": False}
else:
    from jax.experimental.shard_map import shard_map
    _smap_kw = {"check_rep": False}

mesh = jax.sharding.Mesh(np.array(jax.devices()).reshape(8), ("model",))
owner = hashing.fresh("tabulation", 7)
stacked = dd.make_stacked(8, "linear", capacity=256, chunk=64, seed=0)
tspec = jtu.tree_map(lambda _: P("model"), dhash.make("linear", 256, chunk=64))
stacked = jtu.tree_map(
    lambda x: jax.device_put(x, NamedSharding(mesh, P("model"))), stacked)

keys = jnp.arange(1, 513, dtype=jnp.int32)
vals = keys * 3

@partial(shard_map, mesh=mesh, **_smap_kw,
         in_specs=(tspec, P("model"), P("model"), P("model"), P("model")),
         out_specs=(tspec, P("model")))
def service(dstack, lk, ik, iv, dk):
    d = dd.peel(dstack)
    d, (found, _, stats) = dd.routed_service_step(d, lk, ik, iv, dk, "model", owner)
    return dd.unpeel(d), stats[None]

# step 1: insert everything (lookups miss), step 2: all lookups hit
z = jnp.zeros((8,), jnp.int32)
stacked, stats = jax.jit(service)(stacked, keys, keys, vals, z)
stacked, stats = jax.jit(service)(stacked, keys, z, z, z)
found_total = int(np.asarray(stats)[:, 0].sum())
assert found_total == 512, found_total

# capped routing agrees with uncapped under uniform keys
@partial(shard_map, mesh=mesh, **_smap_kw,
         in_specs=(tspec, P("model")), out_specs=(P("model"), P("model")))
def lookup_capped(dstack, lk):
    d = dd.peel(dstack)
    f, v = dd.routed_lookup(d, lk, "model", owner, cap=lk.shape[0] // 2)
    return f, v

f, v = jax.jit(lookup_capped)(stacked, keys)
f, v = np.asarray(f), np.asarray(v)
assert f.sum() >= 500, f.sum()        # a few may exceed per-owner cap
assert (v[f] == np.asarray(keys)[f] * 3).all()

# shard-local rebuild with synchronized epochs: all data survives
for _ in range(64):
    stacked, _ = jax.jit(service)(stacked, z, z, z, z)  # rebuild_step x64

print("DIST-OK")
"""


def test_distributed_dhash_8dev():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-3000:]
    assert "DIST-OK" in r.stdout


# -- the S×T grid: routed stack ops over mesh-sharded tenant stacks ----------
SCRIPT_GRID = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
import jax.tree_util as jtu
from functools import partial
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core import backend, dhash, distributed as dd, hashing

if hasattr(jax, "shard_map"):
    shard_map, _smap_kw = jax.shard_map, {"check_vma": False}
else:
    from jax.experimental.shard_map import shard_map
    _smap_kw = {"check_rep": False}

S, T, QL = 2, 3, 48                      # 2 shards x 3 tenants, 48 queries/shard
mesh = jax.sharding.Mesh(np.array(jax.devices()[:S]), ("grid",))
owner = hashing.fresh("tabulation", 7)
rng = np.random.default_rng(0)
keys = jnp.asarray(rng.choice(100_000, S * QL, replace=False).astype(np.int32)) + 1
tenant = jnp.asarray(rng.integers(0, T, S * QL).astype(np.int32))
vals = keys * 5
own_np = np.asarray(dd.grid_owner(keys, tenant, S, T, owner))

for name in backend.names():
    for fused in ((False, True) if backend.get(name).fused else (False,)):
        full = dhash.make_stack(S * T, name, 128, chunk=64, seed=5, fused=fused)
        grid = jtu.tree_map(lambda x: x.reshape((S, T) + x.shape[1:]), full)
        gspec = jtu.tree_map(lambda _: P("grid"), grid)
        sh = lambda x: jax.device_put(x, NamedSharding(mesh, P("grid")))
        grid = jtu.tree_map(sh, grid)

        @partial(shard_map, mesh=mesh, **_smap_kw,
                 in_specs=(gspec, P("grid"), P("grid"), P("grid")),
                 out_specs=(gspec, P("grid"), P("grid")))
        def g_insert(g, k, v, tn):
            d = dd.peel(g)
            d, ok, ov = dd.routed_stack_update(
                d, k, v, jnp.ones(k.shape, bool), tn, "grid", owner,
                op=dhash.stack_insert, cap_factor=0.0)
            return dd.unpeel(d), ok, ov[None]

        @partial(shard_map, mesh=mesh, **_smap_kw,
                 in_specs=(gspec, P("grid"), P("grid")),
                 out_specs=(P("grid"), P("grid"), P("grid")))
        def g_lookup(g, k, tn):
            f, v, ov = dd.routed_stack_lookup(
                dd.peel(g), k, tn, "grid", owner, cap_factor=0.0)
            return f, v, ov[None]

        @partial(shard_map, mesh=mesh, **_smap_kw,
                 in_specs=(gspec, P("grid")), out_specs=gspec)
        def g_autostart(g, m):
            return dd.unpeel(dhash.stack_autostart(dd.peel(g), m[0]))

        @partial(shard_map, mesh=mesh, **_smap_kw,
                 in_specs=(gspec,), out_specs=gspec)
        def g_step(g):
            return dd.unpeel(dhash.stack_finish_same_shape(
                dhash.stack_rebuild_step(dd.peel(g))))

        grid, ok, ov = jax.jit(g_insert)(grid, keys, vals, tenant)
        assert bool(np.asarray(ok).all()), (name, fused, "insert dropped keys")
        assert int(np.asarray(ov).sum()) == 0

        # staggered epochs: (shard 0, tenant 0) and (shard 1, tenant 2) only
        started = np.array([[True, False, False], [False, False, True]])
        grid = jax.jit(g_autostart)(grid, jnp.asarray(started))
        lk = jax.jit(g_lookup)
        st = jax.jit(g_step)
        for step in range(16):
            grid = st(grid)
            if step in (0, 7, 15):     # mid-rebuild resolution never blocks
                f, v, _ = lk(grid, keys, tenant)
                assert bool(np.asarray(f).all()), (name, fused, step)
                np.testing.assert_array_equal(np.asarray(v), np.asarray(vals))
        ep = np.asarray(jax.device_get(grid.epoch))
        np.testing.assert_array_equal(ep, started.astype(ep.dtype))
        reb = np.asarray(jax.device_get(grid.rebuilding))
        assert not reb.any(), (name, fused, "rebuilds must complete")

        # parity vs the single-device stack_* ops on the SAME final tables
        merged = jtu.tree_map(
            lambda x: jnp.reshape(jax.device_get(x), (S * T,) + x.shape[2:]),
            grid)
        rt = dd._route(keys, jnp.asarray(own_np), S * T)
        f1, v1 = dhash.stack_lookup(merged, rt.send, rt.smask)
        f, v, _ = lk(grid, keys, tenant)
        np.testing.assert_array_equal(
            np.asarray(f), np.asarray(dd._unroute(f1, rt, fill=False)))
        np.testing.assert_array_equal(
            np.asarray(v)[np.asarray(f)],
            np.asarray(dd._unroute(v1, rt, fill=0))[np.asarray(f)])

print("GRID-PARITY-OK")

# adversarial all-keys-one-tenant batch on the CAPPED path: the
# overflow-proof spill slab serves EVERY key in the single pass (no retry
# exists any more) while the overflow counters stay exact per shard-local
# batch
def fresh_grid(seed):
    g = jtu.tree_map(lambda x: x.reshape((S, T) + x.shape[1:]),
                     dhash.make_stack(S * T, "linear", 128, chunk=64,
                                      seed=seed, fused=True))
    return jtu.tree_map(lambda x: jax.device_put(
        x, NamedSharding(mesh, P("grid"))), g)

grid = fresh_grid(9)
gspec = jtu.tree_map(lambda _: P("grid"), grid)
akeys = jnp.asarray(rng.choice(100_000, S * QL, replace=False)
                    .astype(np.int32)) + 200_000
atn = jnp.ones((S * QL,), jnp.int32)            # 100% skew: tenant 1
CF = 2.0
cap = dd.route_cap(CF, QL, S * T)

def make_capped(slack):
    @partial(shard_map, mesh=mesh, **_smap_kw,
             in_specs=(gspec, P("grid"), P("grid"), P("grid")),
             out_specs=(gspec, P("grid"), P("grid")))
    def g_ins(g, k, v, tn):
        d = dd.peel(g)
        d, ok, ov = dd.routed_stack_update(
            d, k, v, jnp.ones(k.shape, bool), tn, "grid", owner,
            op=dhash.stack_insert, cap_factor=CF, spill_slack=slack)
        return dd.unpeel(d), ok, ov[None]

    @partial(shard_map, mesh=mesh, **_smap_kw,
             in_specs=(gspec, P("grid"), P("grid")),
             out_specs=(P("grid"), P("grid"), P("grid")))
    def g_lk(g, k, tn):
        f, v, ov = dd.routed_stack_lookup(
            dd.peel(g), k, tn, "grid", owner, cap_factor=CF,
            spill_slack=slack)
        return f, v, ov[None]
    return g_ins, g_lk

g_insert_capped, g_lookup_capped = make_capped(None)
grid, ok, ov = jax.jit(g_insert_capped)(grid, akeys, akeys * 5, atn)
ok, ov = np.asarray(ok), np.asarray(ov)
aown = np.asarray(dd.grid_owner(akeys, atn, S, T, owner))
exp_ov = np.stack([np.maximum(np.bincount(
    aown[i * QL:(i + 1) * QL], minlength=S * T) - cap, 0) for i in range(S)])
np.testing.assert_array_equal(ov, exp_ov)       # EXACT per-owner overflow
assert exp_ov.sum() > 0, "adversarial batch must overflow the cap"
assert ok.sum() == S * QL, "overflow-proof slab must serve every key"

@partial(shard_map, mesh=mesh, **_smap_kw,
         in_specs=(gspec, P("grid"), P("grid")),
         out_specs=(P("grid"), P("grid"), P("grid")))
def g_lookup_full(g, k, tn):
    f, v, ov = dd.routed_stack_lookup(
        dd.peel(g), k, tn, "grid", owner, cap_factor=0.0)
    return f, v, ov[None]

f, v, _ = jax.jit(g_lookup_full)(grid, akeys, atn)
f = np.asarray(f)
assert f.all(), "every slab-served insert must be visible full-width"
np.testing.assert_array_equal(np.asarray(v), np.asarray(akeys * 5))
print("GRID-CAP-OK")

# compact slab: slab-exhausted keys are EXACTLY accounted (ok=False per
# key, never silently lost) and the table holds precisely the served set
SL = 0.125
spill_cap = dd.route_spill_cap(QL, cap, SL)
assert 0 < spill_cap < QL - cap
grid2 = fresh_grid(11)
g_insert_compact, _ = make_capped(SL)
grid2, ok2, _ = jax.jit(g_insert_compact)(grid2, akeys, akeys * 5, atn)
ok2 = np.asarray(ok2)
exp_served = np.array([QL - max(int(exp_ov[i].sum()) - spill_cap, 0)
                       for i in range(S)])
assert (exp_served < QL).any(), "compact slab must actually drop"
np.testing.assert_array_equal(ok2.reshape(S, QL).sum(axis=1), exp_served)
f2, v2, _ = jax.jit(g_lookup_full)(grid2, akeys, atn)
f2 = np.asarray(f2)
np.testing.assert_array_equal(f2, ok2)          # present iff served
np.testing.assert_array_equal(np.asarray(v2)[f2], np.asarray(akeys * 5)[f2])
print("GRID-DROP-OK")

# jaxpr pins: the routed slab ops stay SINGLE-PASS inside shard_map —
# byte-for-byte the same primitive counts as the full-width
# (cap_factor=0.0) ops, so the slab adds NO pass on top of the
# mid-rebuild-ordered kernels' own structure (the bare-kernel
# 1-sort/1-pallas_call pin lives in test_routing.py where the op IS the
# bare fused lookup).  The retry cond is gone: insert lowers with ZERO
# conds, and lookup's only conds are stack_lookup's own two
# ``d.rebuilding`` ordering gates (dhash.py), identical in the
# full-width reference.  Both ops keep ONE all_to_all pair per
# direction on the wire (lookup ships keys+mask out and found+vals
# back = 4; insert ships keys+mask+vals out and ok back = 4) — exactly
# the pre-slab wire count.
from collections import Counter
def prim_counts(fn, *xs):
    ctr = Counter()
    def rec(j):
        for eq in j.eqns:
            ctr[eq.primitive.name] += 1
            for p in eq.params.values():
                if hasattr(p, "eqns"):           # open Jaxpr (shard_map)
                    rec(p)
                elif hasattr(p, "jaxpr"):        # ClosedJaxpr (pjit, ...)
                    rec(p.jaxpr if hasattr(p.jaxpr, "eqns") else p.jaxpr.jaxpr)
    rec(jax.make_jaxpr(fn)(*xs).jaxpr)
    return ctr

@partial(shard_map, mesh=mesh, **_smap_kw,
         in_specs=(gspec, P("grid"), P("grid"), P("grid")),
         out_specs=(gspec, P("grid"), P("grid")))
def g_insert_fullwidth(g, k, v, tn):
    d = dd.peel(g)
    d, ok, ov = dd.routed_stack_update(
        d, k, v, jnp.ones(k.shape, bool), tn, "grid", owner,
        op=dhash.stack_insert, cap_factor=0.0)
    return dd.unpeel(d), ok, ov[None]

pairs = ((prim_counts(g_insert_capped, grid, akeys, akeys * 5, atn),
          prim_counts(g_insert_fullwidth, grid, akeys, akeys * 5, atn),
          "insert", 0),
         (prim_counts(g_lookup_capped, grid, akeys, atn),
          prim_counts(g_lookup_full, grid, akeys, atn),
          "lookup", 2))
for slab, fullw, tag, n_cond in pairs:
    assert slab == fullw, (tag, {k: (slab[k], fullw[k])
                                 for k in set(slab) | set(fullw)
                                 if slab[k] != fullw[k]})
    assert slab["cond"] == n_cond, (tag, slab["cond"])
    assert slab["all_to_all"] == 4, (tag, slab["all_to_all"])
print("GRID-JAXPR-OK")
"""


def test_routed_stack_grid_8dev():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", SCRIPT_GRID],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-3000:]
    assert "GRID-PARITY-OK" in r.stdout
    assert "GRID-CAP-OK" in r.stdout
    assert "GRID-DROP-OK" in r.stdout
    assert "GRID-JAXPR-OK" in r.stdout
