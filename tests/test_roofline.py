"""HLO cost-walker validation: exact flop counts on known programs,
trip-count multiplication, and collective accounting."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_cost


def _analyze(fn, *args):
    text = jax.jit(fn).lower(*args).compile().as_text()
    return hlo_cost.analyze(text)


def test_single_matmul_flops_exact():
    a = jnp.zeros((128, 256), jnp.float32)
    b = jnp.zeros((256, 512), jnp.float32)
    cost = _analyze(lambda a, b: a @ b, a, b)
    assert cost.flops == 2 * 128 * 256 * 512, cost.flops


def test_scan_multiplies_trip_count():
    w = jnp.zeros((10, 64, 64), jnp.float32)
    x = jnp.zeros((8, 64), jnp.float32)

    def fn(w, x):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y

    cost = _analyze(fn, w, x)
    expect = 10 * 2 * 8 * 64 * 64
    # exact trip multiplication of the dot inside the while body
    assert abs(cost.flops - expect) / expect < 0.01, (cost.flops, expect)


def test_nested_scan_multiplies():
    w = jnp.zeros((4, 3, 32, 32), jnp.float32)
    x = jnp.zeros((8, 32), jnp.float32)

    def fn(w, x):
        def outer(c, wo):
            def inner(ci, wi):
                return ci @ wi, None
            c2, _ = jax.lax.scan(inner, c, wo)
            return c2, None
        y, _ = jax.lax.scan(outer, x, w)
        return y

    cost = _analyze(fn, w, x)
    expect = 4 * 3 * 2 * 8 * 32 * 32
    assert abs(cost.flops - expect) / expect < 0.01, (cost.flops, expect)


def test_scan_stash_counts_slices_not_buffer():
    """The DUS writing a scan's stacked outputs must count the slice (x trips
    == one pass over the stack), never the full buffer per trip."""
    x = jnp.zeros((8, 128), jnp.float32)

    def fn(x):
        def body(c, _):
            c = c * 1.5
            return c, c          # stacked output [64, 8, 128]
        _, ys = jax.lax.scan(body, x, None, length=64)
        return ys

    cost = _analyze(fn, x)
    stack_bytes = 64 * 8 * 128 * 4
    # a few stack-sized passes (init + compute + slice writes) is fine; the
    # bug this guards against counts the FULL buffer per trip (~66x+)
    assert cost.bytes < 20 * stack_bytes, (cost.bytes, stack_bytes)


def test_shape_bytes_tuple_and_dtypes():
    assert hlo_cost._shape_bytes("bf16[2,3]") == 12
    assert hlo_cost._shape_bytes("(f32[4], s8[8], pred[2])") == 26
    assert hlo_cost._shape_bytes("token[]") == 0


def test_roofline_terms():
    from repro.launch.analysis import Roofline
    rl = Roofline(chips=256, hlo_flops=197e12 * 256, hlo_bytes=819e9 * 256,
                  coll_bytes=0.0, model_flops=197e12 * 256 / 2)
    assert rl.t_compute == pytest.approx(1.0)
    assert rl.t_memory == pytest.approx(1.0)
    assert rl.bottleneck in ("compute", "memory")
    assert rl.mfu == pytest.approx(0.5)
