"""Table-stack tests: ``dhash.make_stack`` + the vmapped ``stack_*`` ops.

The contract under test: a stack of T tables behaves EXACTLY like T
independently-run tables — lookup/insert/delete results, rebuild progress,
and epoch counters all match a Python loop over the unstacked states, with
rebuild epochs fully staggered across the stack — while the fused
1-sort/1-pallas_call budget holds per table step (vmap batches the kernel
launch over [T] instead of re-issuing it T times).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backend, dhash
from repro.core.engine import DHashStackEngine

T = 8          # acceptance: >= 8 tables
CAP = 384
Q = 64

ALL_BACKENDS = backend.names()
FUSED_AXIS = [(b, f) for b in ALL_BACKENDS
              for f in ((False, True) if backend.get(b).fused else (False,))]


def _count_primitives(closed_jaxpr, names):
    from collections import Counter
    ctr = Counter()

    def rec(jaxpr):
        for eq in jaxpr.eqns:
            ctr[eq.primitive.name] += 1
            for p in eq.params.values():
                if hasattr(p, "jaxpr"):
                    rec(p.jaxpr if hasattr(p.jaxpr, "eqns") else p.jaxpr.jaxpr)

    rec(closed_jaxpr.jaxpr)
    return {n: ctr.get(n, 0) for n in names}


def _keys(rng, t=T, n=CAP):
    return jnp.asarray(rng.choice(1_000_000, (t, n), replace=False)
                       .astype(np.int32)) + 1


def test_make_stack_shape_and_unstack():
    st = dhash.make_stack(T, "linear", CAP, chunk=64, seed=0)
    assert dhash.stack_size(st) == T
    assert st.hazard_key.shape == (T, 64)
    singles = dhash.unstack(st)
    assert len(singles) == T
    # per-table seeds are decorrelated: hash functions differ across tables
    seeds = {tuple(np.asarray(s.old.hfn.seeds).tolist()) for s in singles}
    assert len(seeds) == T
    # unstack inverts the stack exactly
    restacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *singles)
    for a, b in zip(jax.tree_util.tree_leaves(st),
                    jax.tree_util.tree_leaves(restacked)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError):
        dhash.make_stack(0, "linear", CAP)


@pytest.mark.parametrize("name,fused", FUSED_AXIS)
def test_stack_parity_vs_independent_loop(name, fused):
    """The acceptance walk: a T-table stack through insert / staggered
    rebuild epochs / mid-epoch lookup+delete / epoch swaps matches T
    independently-run tables step for step."""
    rng = np.random.default_rng(7)
    st = dhash.make_stack(T, name, CAP, chunk=128, seed=0, fused=fused)
    singles = dhash.unstack(st)
    keys = _keys(rng)
    vals = keys * 5

    ins_s = jax.jit(dhash.stack_insert)
    ins_1 = jax.jit(dhash.insert)
    st, ok = ins_s(st, keys[:, :CAP // 2], vals[:, :CAP // 2])
    for i in range(T):
        singles[i], ok1 = ins_1(singles[i], keys[i, :CAP // 2],
                                vals[i, :CAP // 2])
        np.testing.assert_array_equal(np.asarray(ok[i]), np.asarray(ok1))

    # STAGGERED epochs: every second table starts rebuilding now, the rest
    # stay on the fast path; two of them join three steps later
    mask0 = jnp.asarray([i % 2 == 0 for i in range(T)])
    st = jax.jit(dhash.stack_autostart)(st, mask0)
    auto_1 = jax.jit(dhash.rebuild_autostart)
    for i in range(0, T, 2):
        singles[i] = auto_1(singles[i])

    step_s = jax.jit(lambda d: dhash.stack_finish_same_shape(
        dhash.stack_rebuild_step(d)))
    step_1 = jax.jit(lambda d: dhash.finish_same_shape(dhash.rebuild_step(d)))
    lk_s, lk_1 = jax.jit(dhash.stack_lookup), jax.jit(dhash.lookup)
    del_s, del_1 = jax.jit(dhash.stack_delete), jax.jit(dhash.delete)

    dels = keys[:, :Q]
    ep_trace = []
    for step in range(24):
        if step == 3:
            mask1 = jnp.asarray([i in (1, 3) for i in range(T)])
            st = jax.jit(dhash.stack_autostart)(st, mask1)
            singles[1] = auto_1(singles[1])
            singles[3] = auto_1(singles[3])
        st = step_s(st)
        f, v = lk_s(st, keys[:, :Q])
        if step == 5:
            st, okd = del_s(st, dels)
        for i in range(T):
            singles[i] = step_1(singles[i])
            f1, v1 = lk_1(singles[i], keys[i, :Q])
            np.testing.assert_array_equal(np.asarray(f[i]), np.asarray(f1))
            np.testing.assert_array_equal(np.asarray(v[i]), np.asarray(v1))
            if step == 5:
                singles[i], okd1 = del_1(singles[i], dels[i])
                np.testing.assert_array_equal(np.asarray(okd[i]),
                                              np.asarray(okd1))
        ep_trace.append(np.asarray(st.epoch).copy())

    # epochs are independent AND staggered: started tables progressed
    # exactly like their independent twins, never-started tables are
    # untouched, and at some point mid-run the early starters were a full
    # epoch ahead of the late ones
    ep_s = np.asarray(st.epoch)
    ep_1 = np.array([int(s.epoch) for s in singles])
    np.testing.assert_array_equal(ep_s, ep_1)
    np.testing.assert_array_equal(np.asarray(st.rebuilding),
                                  np.array([bool(s.rebuilding)
                                            for s in singles]))
    started = [i for i in range(T) if i % 2 == 0 or i in (1, 3)]
    idle = [i for i in range(T) if i not in started]
    assert (ep_s[idle] == 0).all()
    assert (ep_s[started] >= 1).all(), "started rebuilds must complete"
    assert any(len(set(ep[started])) > 1 for ep in ep_trace), \
        "staggered starts should spread epochs across the stack mid-run"

    # final contents match per table
    cnt_s = np.asarray(jax.jit(dhash.stack_count_items)(st))
    cnt_1 = np.array([int(dhash.count_items(s)) for s in singles])
    np.testing.assert_array_equal(cnt_s, cnt_1)


@pytest.mark.parametrize("name", [b for b in ALL_BACKENDS
                                  if backend.get(b).fused])
def test_stack_fused_budget_per_table_step(name):
    """The acceptance budget: the whole stack's rebuild-epoch ordered
    lookup — and the fast-path fused lookup — stay ONE sort + ONE
    pallas_call under vmap (the launch is batched over [T], not re-issued
    per table)."""
    be = backend.get(name)
    st = dhash.make_stack(T, name, CAP, chunk=64, seed=0, fused=True)
    keys = _keys(np.random.default_rng(3), n=Q)

    ordered = jax.vmap(lambda d, k: be.ordered_lookup_fused(
        d.old, d.new, d.hazard_key, d.hazard_val, d.hazard_live, k,
        nres_cap=d.nres_cap))
    counts = _count_primitives(jax.make_jaxpr(ordered)(st, keys),
                               ("sort", "pallas_call"))
    assert counts == {"sort": 1, "pallas_call": 1}, (name, counts)

    fast = jax.vmap(lambda d, k: be.lookup_fused(d.old, k))
    counts = _count_primitives(jax.make_jaxpr(fast)(st, keys),
                               ("sort", "pallas_call"))
    assert counts == {"sort": 1, "pallas_call": 1}, (name, counts)


def test_stack_engine_continuous_rebuild():
    """DHashStackEngine: the vmapped step loop sustains per-table op
    batches through continuous independent rebuilds and reports aggregate
    epoch progress."""
    rng = np.random.default_rng(0)
    eng = DHashStackEngine(dhash.make_stack(T, "linear", 128, chunk=32,
                                            seed=0),
                           continuous_rebuild=True, poll_every=4)
    keys = _keys(rng, n=128)
    none_i = np.zeros((T, 1), np.int32)
    for j in range(0, 128, 32):
        eng.step(keys[:, j:j + 32], keys[:, j:j + 32], keys[:, j:j + 32] * 3,
                 none_i, del_mask=np.zeros((T, 1), bool))
    for _ in range(30):
        f, v, _, _ = eng.step(keys[:, :32], none_i, none_i, none_i,
                              ins_mask=np.zeros((T, 1), bool),
                              del_mask=np.zeros((T, 1), bool))
    assert bool(np.asarray(f).all())
    np.testing.assert_array_equal(np.asarray(v), np.asarray(keys[:, :32]) * 3)
    np.testing.assert_array_equal(eng.counts(), np.full(T, 128))
    assert eng.stats.rebuilds_completed >= T, \
        "continuous mode should complete epochs on every table"


def test_stack_engine_masked_request_rebuild():
    eng = DHashStackEngine(dhash.make_stack(4, "twochoice", 256, chunk=32,
                                            seed=0))
    eng.request_rebuild(np.array([True, False, True, False]))
    np.testing.assert_array_equal(
        np.asarray(jax.device_get(eng.state.rebuilding)),
        np.array([True, False, True, False]))
