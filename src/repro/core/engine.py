"""Host-side engine: interleaves full-rate op batches with rebuild transitions.

This is the SPMD rendering of the paper's concurrency: "worker threads"
(batched lookup/insert/delete steps) run at full rate while a rebuild makes
incremental progress — one extract or land transition per engine step, with
the hazard window genuinely observable by the ops interleaved between the two
halves.  The engine also owns the host-level epoch swap (rebuild_finish).

Used by the benchmarks (continuous-rebuild mode reproduces the paper's Fig 2
setup) and by the serving engine for live cache rehash.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dhash

I32 = jnp.int32


@dataclass
class EngineStats:
    steps: int = 0
    ops: int = 0
    hits: int = 0
    rebuilds_completed: int = 0
    rebuild_transitions: int = 0


@dataclass
class DHashEngine:
    """Drives a DHashState: user op batches + background rebuild progress."""

    state: dhash.DHashState
    continuous_rebuild: bool = False   # paper Fig 2: rebuild forever
    rebuild_seed: int = 1234
    stats: EngineStats = field(default_factory=EngineStats)
    _step_fn: Callable | None = None

    def __post_init__(self):
        # one fused jitted transition: ops + one rebuild transition
        def fused(d, lk, ik, iv, dk, imask, dmask):
            found, vals = dhash.lookup(d, lk)
            d, ok_i = dhash.insert(d, ik, iv, imask)
            d, ok_d = dhash.delete(d, dk, dmask)
            d = dhash.rebuild_step(d)
            return d, (found, vals, ok_i, ok_d)

        self._step_fn = jax.jit(fused)

    def step(self, lookup_keys, ins_keys, ins_vals, del_keys,
             ins_mask=None, del_mask=None):
        lk = jnp.asarray(lookup_keys, I32)
        ik = jnp.asarray(ins_keys, I32)
        iv = jnp.asarray(ins_vals, I32)
        dk = jnp.asarray(del_keys, I32)
        im = jnp.ones(ik.shape, bool) if ins_mask is None else jnp.asarray(ins_mask)
        dm = jnp.ones(dk.shape, bool) if del_mask is None else jnp.asarray(del_mask)
        self.state, out = self._step_fn(self.state, lk, ik, iv, dk, im, dm)
        self.stats.steps += 1
        self.stats.ops += lk.size + ik.size + dk.size
        self._maybe_epoch()
        return out

    def request_rebuild(self, *, seed: int | None = None, new_table=None):
        """Begin a live rebuild (fails like the paper's trylock if one is
        already in progress)."""
        if bool(jax.device_get(self.state.rebuilding)):
            return False  # -EBUSY
        self.state = dhash.rebuild_start(
            self.state, new_table,
            seed=self.rebuild_seed if seed is None else seed)
        self.rebuild_seed += 1
        return True

    def _maybe_epoch(self):
        # Poll completion; swap at the host level (the paper's lines 41-46).
        if bool(jax.device_get(dhash.rebuild_done(self.state))):
            self.state = dhash.rebuild_finish(self.state)
            self.stats.rebuilds_completed += 1
            if self.continuous_rebuild:
                self.request_rebuild()
        elif self.continuous_rebuild and not bool(jax.device_get(self.state.rebuilding)):
            self.request_rebuild()

    def lookup(self, keys):
        f, v = jax.jit(dhash.lookup)(self.state, jnp.asarray(keys, I32))
        return f, v

    def count(self) -> int:
        return int(jax.device_get(dhash.count_items(self.state)))
