"""Distributed DHash: the table sharded over a mesh axis.

Ownership is by a *fixed* owner hash (never rebuilt): shard s owns key k iff
``owner_hash(k) % S == s``.  Rebuilds swap each shard's *local* hash function;
because every shard executes the same transition stream (SPMD), the epoch
swap is collectively synchronized for free — the multi-host analogue of the
paper's ``synchronize_rcu`` grace period.

Query routing is one all_to_all pair (there and back), the same dispatch
pattern as MoE token routing; the send buffer is [S, Q] so even a fully
adversarial key set (every key owned by one shard — the paper's collision
attack) routes without overflow, it just concentrates work.

These functions are written to be called INSIDE ``jax.shard_map`` with the
table sharded (one leaf-shard per device along ``axis``) and queries sharded
along their batch dim.  Every shard-local table op dispatches through the
``BucketBackend`` descriptor registry (core/backend.py), so any registered
backend — fused or jnp — shards without changes here.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import dhash, hashing

I32 = jnp.int32


def _axis_size(axis) -> int:
    """Static mesh-axis size, tolerant of the jax API move: ``lax.axis_size``
    arrived after 0.5; on older releases ``psum(1, axis)`` constant-folds to
    the same Python int."""
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis)
    return lax.psum(1, axis)


def _route(keys: jax.Array, owner: jax.Array, nshards: int,
           cap: int | None = None):
    """Group keys by owner shard into a [S, cap] send buffer.

    cap=None (baseline) uses cap=Q — overflow-proof even under a collision
    attack concentrating every key on one owner, at S x the wire bytes.
    The §Perf-optimized path uses cap = c*Q/S (see EXPERIMENTS.md): keys
    beyond an owner's capacity are dropped from the batch (reported via
    smask; a uniform owner hash overflows with negligible probability).
    Returns (send[S,cap], smask[S,cap], order, so, rank, kept[Q sorted]).
    """
    q = keys.shape[0]
    cap = q if cap is None else cap
    order = jnp.argsort(owner)
    sk, so = keys[order], owner[order]
    first = jnp.searchsorted(so, so, side="left")
    rank = jnp.arange(q, dtype=I32) - first.astype(I32)
    kept = rank < cap
    crank = jnp.where(kept, rank, 0)
    cso = jnp.where(kept, so, nshards)
    send = jnp.zeros((nshards, cap), keys.dtype).at[cso, crank].set(
        sk, mode="drop")
    smask = jnp.zeros((nshards, cap), bool).at[cso, crank].set(
        kept, mode="drop")
    return send, smask, order, so, rank, kept


def _route_payload(payload: jax.Array, order, so, rank, kept, nshards: int,
                   cap: int):
    """Scatter a per-key payload (values, masks) into the [S, cap] send
    buffer produced by ``_route`` for the same batch — dropped keys (beyond
    an owner's cap) stay zero.  Shared by the distributed router and the
    serving tenant router."""
    cso = jnp.where(kept, so, nshards)
    crank = jnp.where(kept, rank, 0)
    return jnp.zeros((nshards, cap), payload.dtype).at[cso, crank].set(
        payload[order], mode="drop")


def _unroute(resp_local: jax.Array, order, so, rank, kept, q, fill=0):
    """Invert _route for a [S, cap] response."""
    gathered = jnp.where(
        kept,
        resp_local[jnp.where(kept, so, 0), jnp.where(kept, rank, 0)],
        jnp.asarray(fill, resp_local.dtype))
    inv = jnp.zeros((q,), I32).at[order].set(jnp.arange(q, dtype=I32))
    return gathered[inv]


def routed_lookup(d: dhash.DHashState, keys: jax.Array, axis: str,
                  owner_hfn: hashing.HashFn, cap: int | None = None):
    """DHash lookup across shards. Call inside shard_map."""
    s = _axis_size(axis)
    q = keys.shape[0]
    owner = (hashing.hash_u32(owner_hfn, keys) % jnp.uint32(s)).astype(I32)
    send, smask, order, so, rank, kept = _route(keys, owner, s, cap)
    c = send.shape[1]
    rk = lax.all_to_all(send, axis, split_axis=0, concat_axis=0)
    rm = lax.all_to_all(smask, axis, split_axis=0, concat_axis=0)
    found, vals = dhash.lookup(d, rk.reshape(-1))
    found = found & rm.reshape(-1)
    rf = lax.all_to_all(found.reshape(s, c), axis, split_axis=0, concat_axis=0)
    rv = lax.all_to_all(vals.reshape(s, c), axis, split_axis=0, concat_axis=0)
    return (_unroute(rf, order, so, rank, kept, q).astype(bool),
            _unroute(rv, order, so, rank, kept, q))


def routed_update(d: dhash.DHashState, keys: jax.Array, vals: jax.Array,
                  mask: jax.Array, axis: str, owner_hfn: hashing.HashFn,
                  op: Callable = dhash.insert, cap: int | None = None):
    """DHash insert/delete across shards. Returns (d', ok). Call inside shard_map."""
    s = _axis_size(axis)
    q = keys.shape[0]
    owner = (hashing.hash_u32(owner_hfn, keys) % jnp.uint32(s)).astype(I32)
    send, smask, order, so, rank, kept = _route(keys, owner, s, cap)
    c = send.shape[1]
    sendv = _route_payload(vals, order, so, rank, kept, s, c)
    sm2 = _route_payload(mask, order, so, rank, kept, s, c)
    rk = lax.all_to_all(send, axis, split_axis=0, concat_axis=0)
    rv = lax.all_to_all(sendv, axis, split_axis=0, concat_axis=0)
    rm = lax.all_to_all(sm2, axis, split_axis=0, concat_axis=0)
    if op is dhash.insert:
        d, ok = op(d, rk.reshape(-1), rv.reshape(-1), rm.reshape(-1))
    else:
        d, ok = op(d, rk.reshape(-1), rm.reshape(-1))
    rok = lax.all_to_all(ok.reshape(s, c), axis, split_axis=0, concat_axis=0)
    return d, _unroute(rok, order, so, rank, kept, q).astype(bool)


def routed_rebuild_step(d: dhash.DHashState, axis: str) -> dhash.DHashState:
    """One rebuild transition on every shard (SPMD-synchronized epochs)."""
    return dhash.rebuild_step(d)


def make_stacked(nshards: int, backend: str = "linear", capacity: int = 1024,
                 *, chunk: int = 256, seed: int = 0, **kw) -> dhash.DHashState:
    """Build ``nshards`` independent shard tables stacked on a leading axis
    (``dhash.make_stack`` — the same uniform-pytree stack the vmap ops
    batch; here the leading axis is sharded over the mesh instead).

    Shard the leading axis over the mesh axis, then inside shard_map peel it
    with ``tree_map(lambda x: x[0], stacked)`` — see ``shardwise``.
    """
    return dhash.make_stack(nshards, backend, capacity, chunk=chunk,
                            seed=seed, **kw)


def peel(stacked):
    """Inside shard_map: view this shard's table (leading axis is size 1)."""
    return jax.tree_util.tree_map(lambda x: x[0], stacked)


def unpeel(d):
    """Inverse of peel for returning the updated shard."""
    return jax.tree_util.tree_map(lambda x: x[None], d)


def routed_service_step(d: dhash.DHashState, lookup_keys: jax.Array,
                        ins_keys: jax.Array, ins_vals: jax.Array,
                        del_keys: jax.Array, axis: str,
                        owner_hfn: hashing.HashFn, cap_factor: float = 0.0):
    """The paper's steady-state workload as one fused distributed step:
    a lookup batch + insert batch + delete batch + one rebuild transition.
    This is what the dry-run lowers for the dhash_paper 'architecture'.

    cap_factor > 0 bounds the routing buffers at cap = cap_factor * Q / S
    (§Perf lever: S x fewer wire bytes and S x smaller remote batches)."""
    s = _axis_size(axis)
    capof = (lambda q: max(int(cap_factor * q / s), 1)) if cap_factor > 0 \
        else (lambda q: None)
    found, vals = routed_lookup(d, lookup_keys, axis, owner_hfn,
                                cap=capof(lookup_keys.shape[0]))
    d, ok_i = routed_update(d, ins_keys, ins_vals,
                            jnp.ones(ins_keys.shape, bool), axis, owner_hfn,
                            op=dhash.insert, cap=capof(ins_keys.shape[0]))
    d, ok_d = routed_update(d, del_keys, del_keys,
                            jnp.ones(del_keys.shape, bool), axis, owner_hfn,
                            op=dhash.delete, cap=capof(del_keys.shape[0]))
    d = dhash.rebuild_step(d)
    stats = jnp.stack([found.sum(dtype=I32), ok_i.sum(dtype=I32), ok_d.sum(dtype=I32)])
    return d, (found, vals, stats)
